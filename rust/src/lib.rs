//! # StashCache — a distributed caching federation
//!
//! Reproduction of *StashCache: A Distributed Caching Federation for the
//! Open Science Grid* (Weitzel et al., PEARC '19) as a three-layer
//! rust + JAX + Pallas stack.
//!
//! The federation has four components (paper §3, Figure 1):
//!
//! * **Data origins** ([`origin`]) — the authoritative source of data,
//!   registered for a subset of the global [`namespace`].
//! * **Redirector** ([`redirector`]) — the data-discovery service; caches
//!   query it to find which origin holds a path. Deployed as a
//!   round-robin HA pair. Cache *selection* is its pluggable policy
//!   layer ([`redirector::policy`]): GeoIP-nearest (the paper's rule),
//!   least-loaded, consistent-hash namespace sharding, or a tiered
//!   site-local → regional → origin ladder.
//! * **Data caches** ([`cache`]) — regional chunk caches that capture
//!   client requests, fetch misses from origins via the redirector, and
//!   manage cache space with watermark LRU eviction.
//! * **Clients** ([`client`]) — `stashcp` (3-method fallback), a
//!   CVMFS-like chunked POSIX reader, and a plain curl/HTTP client. The
//!   client picks the nearest cache by GeoIP ([`geoip`]).
//!
//! The evaluation baseline — site squid HTTP forward proxies — is in
//! [`proxy`]. Usage accounting flows through the XRootD-style
//! [`monitoring`] pipeline (UDP packets → collector → bus → aggregator).
//! Scheduled component failures — cache hosts, links, origins,
//! redirector instances dying mid-transfer — live in [`fault`] and are
//! applied by the session engine as first-class events; sessions fail
//! over across caches and, as a last resort, stream from the origin.
//! The session protocol itself is checked by a small-scope model
//! checker ([`mc`]) that exhaustively enumerates event interleavings
//! on tiny scenarios and asserts global invariants at every state.
//!
//! Because the paper's testbed is the production OSG WAN, the links and
//! sites are reproduced by a deterministic flow-level discrete-event
//! simulator ([`netsim`]); the same services also run as real TCP/UDP
//! processes on loopback ([`live`]). Workloads, the DAGMan-style test
//! scenario, and the drivers that regenerate every paper table/figure
//! live in [`sim`] and [`report`]. The [`experiment`] lab fans whole
//! parameter grids of such runs out across OS threads —
//! deterministically — and reports proxy-vs-StashCache frontiers.
//! Runtime observability of all of the above — engine phase-span
//! histograms, per-cache windowed rollups, Prometheus-style exposition
//! — is the always-on [`telemetry`] layer, deliberately kept off the
//! engine's bit-identity surface.
//!
//! Numeric hot-spots (GeoIP nearest-cache scoring, monitoring histogram
//! aggregation, WAN transfer-time estimation) are AOT-compiled from
//! JAX + Pallas to HLO at build time and executed from rust through
//! PJRT ([`runtime`]). Python is never on the request path.

pub mod cache;
pub mod client;
pub mod config;
pub mod experiment;
pub mod fault;
pub mod federation;
pub mod geoip;
pub mod live;
pub mod mc;
pub mod metrics;
pub mod monitoring;
pub mod namespace;
pub mod netsim;
pub mod origin;
pub mod proxy;
pub mod redirector;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod util;

/// Library-wide result type.
pub type Result<T> = anyhow::Result<T>;
