//! Plain HTTP download client (curl) — the baseline path of §4.1.
//!
//! "The first time it uses curl to download through the HTTP cache."
//! The proxy address comes from the job environment (`http_proxy`), so
//! there is no nearest-service lookup: "the HTTP client has the
//! nearest proxy provided to it from the environment" (§5). curl is
//! also stashcp's third fallback, pointed at a cache's HTTP interface
//! instead of the proxy.

use crate::util::Duration;

/// Simple request description for the drivers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    pub url: String,
    pub bytes: u64,
    /// Via the site forward proxy (baseline) or direct to a cache's
    /// HTTP interface (stashcp fallback).
    pub via_proxy: bool,
}

/// Connection overheads of a bare curl invocation.
#[derive(Debug, Clone, Copy)]
pub struct CurlCosts {
    /// Process spawn + TLS-less TCP connect to the proxy.
    pub startup: Duration,
    /// Extra round trip for the HTTP request/response headers.
    pub request_overhead: Duration,
}

impl Default for CurlCosts {
    fn default() -> Self {
        CurlCosts {
            startup: Duration::from_millis(25),
            request_overhead: Duration::from_millis(5),
        }
    }
}

impl CurlCosts {
    /// Total pre-first-byte latency (excluding network RTT, which the
    /// topology charges separately).
    pub fn pre_transfer(&self) -> Duration {
        self.startup + self.request_overhead
    }
}

/// Build the URL a federation path is served under by proxies/caches.
pub fn url_for(path: &str) -> String {
    format!("http://stash.osgconnect.net:8000{path}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn url_mapping() {
        assert_eq!(
            url_for("/ospool/ligo/f.gwf"),
            "http://stash.osgconnect.net:8000/ospool/ligo/f.gwf"
        );
    }

    #[test]
    fn pre_transfer_sums() {
        let c = CurlCosts::default();
        assert_eq!(c.pre_transfer().as_micros(), 30_000);
    }
}
