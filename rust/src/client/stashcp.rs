//! `stashcp` — the simple copy client (paper §3.1).
//!
//! "stashcp attempts 3 different methods to download the data:
//!  (1) If CVMFS is available on the resource, copy the data from CVMFS
//!  (2) If an XRootD client is available, it will download using
//!      XRootD clients.
//!  (3) If the above two methods fail, it will attempt to download
//!      with curl and the HTTP interface on the caches."
//!
//! "stashcp has a larger startup time which decreases its average
//! performance. The stashcp has to determine the nearest cache, which
//! requires querying a remote server, then can start the transfer" —
//! modelled by [`StartupCosts`]: a GeoIP service round trip plus
//! per-method tool spin-up, charged before the first byte moves.

use super::Method;
use crate::util::Duration;

/// Which tools exist on the execute host (differs per OSG site).
#[derive(Debug, Clone, Copy)]
pub struct HostEnvironment {
    pub cvmfs_mounted: bool,
    pub xrootd_client: bool,
    // curl is always present on OSG worker nodes.
}

impl Default for HostEnvironment {
    fn default() -> Self {
        // The common case on OSG: no CVMFS mount for stash (§3.1 calls
        // stashcp "useful when CVMFS is not installed"), xrdcp present.
        HostEnvironment {
            cvmfs_mounted: false,
            xrootd_client: true,
        }
    }
}

/// Fixed latencies charged before a transfer's first byte.
#[derive(Debug, Clone, Copy)]
pub struct StartupCosts {
    /// Nearest-cache determination: one round trip to the CVMFS GeoIP
    /// service ("querying a remote server").
    pub geoip_lookup: Duration,
    /// Python interpreter + tool startup for stashcp itself.
    pub tool_startup: Duration,
    /// Per-attempt connection establishment to a cache.
    pub connect: Duration,
    /// curl startup when using the HTTP proxy path (the baseline's
    /// "nearest proxy provided to it from the environment" — no
    /// remote lookup, §5).
    pub curl_startup: Duration,
}

impl Default for StartupCosts {
    fn default() -> Self {
        StartupCosts {
            geoip_lookup: Duration::from_millis(450),
            tool_startup: Duration::from_millis(350),
            connect: Duration::from_millis(120),
            curl_startup: Duration::from_millis(25),
        }
    }
}

/// The ordered fallback chain stashcp will walk on this host.
pub fn method_chain(env: HostEnvironment) -> Vec<Method> {
    let mut chain = Vec::new();
    if env.cvmfs_mounted {
        chain.push(Method::Cvmfs);
    }
    if env.xrootd_client {
        chain.push(Method::Xrootd);
    }
    chain.push(Method::HttpCache);
    chain
}

/// Startup latency before the first transfer byte for a given method,
/// assuming it is attempt number `attempt` (0-based) in the chain —
/// each failed attempt already paid its own connect cost.
pub fn startup_latency(costs: &StartupCosts, method: Method, attempt: usize) -> Duration {
    let base = match method {
        // CVMFS has the GeoIP answer cached by its own infrastructure;
        // stashcp-on-cvmfs still pays tool startup.
        Method::Cvmfs => costs.tool_startup,
        // xrdcp / curl-to-cache need the nearest-cache query first.
        Method::Xrootd | Method::HttpCache => {
            costs.tool_startup + costs.geoip_lookup + costs.connect
        }
        // The baseline: proxy address comes from the environment.
        Method::HttpProxy => costs.curl_startup,
        // Direct-to-origin fallback: plain curl against the origin's
        // HTTP interface — no GeoIP query, one fresh connection.
        Method::HttpOrigin => costs.curl_startup + costs.connect,
    };
    // Retries pay an extra connect per failed predecessor.
    base + costs.connect * attempt as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_chain_order() {
        let chain = method_chain(HostEnvironment {
            cvmfs_mounted: true,
            xrootd_client: true,
        });
        assert_eq!(chain, vec![Method::Cvmfs, Method::Xrootd, Method::HttpCache]);
    }

    #[test]
    fn chain_without_cvmfs() {
        let chain = method_chain(HostEnvironment::default());
        assert_eq!(chain, vec![Method::Xrootd, Method::HttpCache]);
    }

    #[test]
    fn bare_host_still_has_curl() {
        let chain = method_chain(HostEnvironment {
            cvmfs_mounted: false,
            xrootd_client: false,
        });
        assert_eq!(chain, vec![Method::HttpCache]);
    }

    #[test]
    fn stashcp_startup_exceeds_proxy_startup() {
        // The §5 observation that makes small files lose on StashCache.
        let c = StartupCosts::default();
        let stash = startup_latency(&c, Method::Xrootd, 0);
        let proxy = startup_latency(&c, Method::HttpProxy, 0);
        assert!(
            stash.as_secs_f64() > 10.0 * proxy.as_secs_f64(),
            "stash {stash} vs proxy {proxy}"
        );
    }

    #[test]
    fn retries_accumulate_connect_cost() {
        let c = StartupCosts::default();
        let first = startup_latency(&c, Method::HttpCache, 0);
        let third = startup_latency(&c, Method::HttpCache, 2);
        assert_eq!(third.as_micros() - first.as_micros(), 2 * c.connect.as_micros());
    }
}
