//! CVMFS client — read-only POSIX interface to the federation (§3.1).
//!
//! "CVMFS provides a read-only POSIX interface to the StashCache
//! federation. ... CVMFS will download the data in small chunks of
//! 24MB. If an application only reads portions of a file, CVMFS will
//! only download those portions. CVMFS is configured to only cache 1GB
//! on the local hard drive."
//!
//! [`CvmfsClient`] models the worker-node side: a chunk-granular local
//! LRU cache (default 1 GB) in front of the remote StashCache cache.
//! [`CvmfsClient::plan_read`] returns which chunks are satisfied
//! locally and which must be requested from the cache; the driver (sim
//! or live) performs the remote I/O and calls
//! [`CvmfsClient::commit_chunks`]. Reads also verify chunk checksums
//! against the mounted catalog when one is supplied (§6: "CVMFS
//! calculates checksums of the data, which guarantees consistency").

use crate::util::ByteSize;
use std::collections::HashMap;

/// CVMFS's fixed chunk size (24 MB, §3.1).
pub const CVMFS_CHUNK: u64 = 24_000_000;

/// Default local hard-drive cache (1 GB, §3.1).
pub const LOCAL_CACHE: u64 = 1_000_000_000;

/// A planned POSIX read.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CvmfsReadPlan {
    /// Bytes served from the worker-local cache.
    pub local_bytes: u64,
    /// Bytes that must come from the StashCache cache.
    pub remote_bytes: u64,
    /// (chunk_index, chunk_offset_in_file, chunk_len) to request
    /// remotely — whole chunks, clipped to file size.
    pub remote_chunks: Vec<(u64, u64, u64)>,
}

#[derive(Debug, Clone, Copy)]
struct LocalChunk {
    len: u64,
    last_access: u64,
}

/// The worker-node CVMFS client state.
#[derive(Debug)]
pub struct CvmfsClient {
    capacity: u64,
    usage: u64,
    clock: u64,
    /// (path, chunk_idx) → chunk residency.
    chunks: HashMap<(String, u64), LocalChunk>,
    pub stats: CvmfsStats,
}

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CvmfsStats {
    pub reads: u64,
    pub local_hit_bytes: u64,
    pub remote_bytes: u64,
    pub evictions: u64,
    pub checksum_failures: u64,
}

impl Default for CvmfsClient {
    fn default() -> Self {
        Self::new(ByteSize(LOCAL_CACHE))
    }
}

impl CvmfsClient {
    pub fn new(local_capacity: ByteSize) -> Self {
        CvmfsClient {
            capacity: local_capacity.as_u64(),
            usage: 0,
            clock: 0,
            chunks: HashMap::new(),
            stats: CvmfsStats::default(),
        }
    }

    pub fn usage(&self) -> ByteSize {
        ByteSize(self.usage)
    }

    /// Plan a POSIX read of `[offset, offset+len)` — only the touched
    /// 24 MB chunks are fetched ("If an application only reads portions
    /// of a file, CVMFS will only download those portions").
    pub fn plan_read(&mut self, path: &str, offset: u64, len: u64, file_size: u64) -> CvmfsReadPlan {
        assert!(
            offset.checked_add(len).is_some_and(|e| e <= file_size),
            "read past EOF"
        );
        self.stats.reads += 1;
        self.clock += 1;
        let mut plan = CvmfsReadPlan {
            local_bytes: 0,
            remote_bytes: 0,
            remote_chunks: Vec::new(),
        };
        if len == 0 {
            return plan;
        }
        let first = offset / CVMFS_CHUNK;
        let last = (offset + len - 1) / CVMFS_CHUNK;
        for c in first..=last {
            let c_start = c * CVMFS_CHUNK;
            let c_len = (c_start + CVMFS_CHUNK).min(file_size) - c_start;
            let lo = offset.max(c_start);
            let hi = (offset + len).min(c_start + c_len);
            let req = hi - lo;
            let key = (path.to_string(), c);
            if let Some(chunk) = self.chunks.get_mut(&key) {
                chunk.last_access = self.clock;
                plan.local_bytes += req;
                self.stats.local_hit_bytes += req;
            } else {
                plan.remote_bytes += req;
                plan.remote_chunks.push((c, c_start, c_len));
            }
        }
        plan
    }

    /// Store fetched chunks in the local cache, optionally verifying
    /// their checksums against the mounted catalog entry. Returns
    /// `false` (and stores nothing) on a checksum mismatch.
    pub fn commit_chunks(
        &mut self,
        path: &str,
        mtime: u64,
        chunks: &[(u64, u64, u64)],
        catalog: Option<&crate::origin::indexer::IndexEntry>,
    ) -> bool {
        if let Some(entry) = catalog {
            if let Some(sums) = &entry.checksums {
                for &(c, c_start, c_len) in chunks {
                    let got =
                        crate::origin::content::extent_checksum(path, mtime, c_start, c_len);
                    if sums.get(c as usize) != Some(&got) {
                        self.stats.checksum_failures += 1;
                        return false;
                    }
                }
            }
        }
        for &(c, _, c_len) in chunks {
            self.clock += 1;
            // Evict LRU chunks until this one fits.
            while self.usage + c_len > self.capacity && !self.chunks.is_empty() {
                let victim = self
                    .chunks
                    .iter()
                    .min_by_key(|(_, ch)| ch.last_access)
                    .map(|(k, _)| k.clone())
                    .expect("non-empty");
                let evicted = self.chunks.remove(&victim).expect("exists");
                self.usage -= evicted.len;
                self.stats.evictions += 1;
            }
            if c_len > self.capacity {
                continue; // chunk larger than the whole local cache
            }
            let key = (path.to_string(), c);
            if let Some(prev) = self.chunks.insert(
                key,
                LocalChunk { len: c_len, last_access: self.clock },
            ) {
                self.usage -= prev.len;
            }
            self.usage += c_len;
            self.stats.remote_bytes += c_len;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::namespace::OriginId;
    use crate::origin::indexer::{Index, Indexer};
    use crate::origin::{FileMeta, Origin};

    #[test]
    fn chunked_partial_read() {
        let mut c = CvmfsClient::default();
        // 100 MB file; read bytes [30 MB, 50 MB): chunks 1 and 2.
        let plan = c.plan_read("/f", 30_000_000, 20_000_000, 100_000_000);
        assert_eq!(plan.remote_chunks.len(), 2);
        assert_eq!(plan.remote_chunks[0].0, 1);
        assert_eq!(plan.remote_chunks[1].0, 2);
        assert_eq!(plan.remote_bytes, 20_000_000);
        // Only the touched chunks are fetched: 2 × 24 MB, not 100 MB.
        let fetched: u64 = plan.remote_chunks.iter().map(|&(_, _, l)| l).sum();
        assert_eq!(fetched, 48_000_000);
    }

    #[test]
    fn local_cache_hit_after_commit() {
        let mut c = CvmfsClient::default();
        let plan = c.plan_read("/f", 0, 10, 100_000_000);
        c.commit_chunks("/f", 1, &plan.remote_chunks, None);
        let plan2 = c.plan_read("/f", 5, 10, 100_000_000);
        assert_eq!(plan2.local_bytes, 10);
        assert_eq!(plan2.remote_bytes, 0);
    }

    #[test]
    fn one_gb_limit_evicts_lru() {
        let mut c = CvmfsClient::default(); // 1 GB
        // 50 chunks of 24 MB = 1.2 GB > 1 GB: early chunks evicted.
        let size = 50 * CVMFS_CHUNK;
        let plan = c.plan_read("/big", 0, size, size);
        c.commit_chunks("/big", 1, &plan.remote_chunks, None);
        assert!(c.usage().as_u64() <= LOCAL_CACHE);
        assert!(c.stats.evictions > 0);
        // Chunk 0 (LRU) gone; last chunk resident.
        let tail = c.plan_read("/big", size - 10, 10, size);
        assert_eq!(tail.local_bytes, 10);
        let head = c.plan_read("/big", 0, 10, size);
        assert_eq!(head.remote_bytes, 10);
    }

    #[test]
    fn checksum_verification_against_catalog() {
        // Index a real origin file, then verify honest and corrupted
        // transfers against the catalog.
        let mut o = Origin::new(OriginId(0), "o", "/data");
        o.put_file("/data/f", FileMeta { size: 60_000_000, mtime: 5, perm: 0o644 })
            .unwrap();
        let indexer = Indexer::default(); // 24 MB chunks, checksums on
        let mut index = Index::default();
        indexer.scan(&o, &mut index);
        let entry = index.get("/data/f").unwrap();

        let mut c = CvmfsClient::default();
        let plan = c.plan_read("/data/f", 0, 1_000, 60_000_000);
        // Honest content (mtime matches) verifies.
        assert!(c.commit_chunks("/data/f", 5, &plan.remote_chunks, Some(entry)));
        // Stale content (old mtime) fails checksum and stores nothing.
        let mut c2 = CvmfsClient::default();
        let plan2 = c2.plan_read("/data/f", 0, 1_000, 60_000_000);
        assert!(!c2.commit_chunks("/data/f", 4, &plan2.remote_chunks, Some(entry)));
        assert_eq!(c2.stats.checksum_failures, 1);
        assert_eq!(c2.usage().as_u64(), 0);
    }

    #[test]
    fn zero_len_read_is_noop() {
        let mut c = CvmfsClient::default();
        let plan = c.plan_read("/f", 10, 0, 100);
        assert_eq!(plan, CvmfsReadPlan { local_bytes: 0, remote_bytes: 0, remote_chunks: vec![] });
    }

    #[test]
    fn property_local_usage_bounded() {
        use crate::util::prop::check;
        check("cvmfs local cache bounded", 40, |g| {
            let cap = g.u64(10, 200) * 1_000_000;
            let mut c = CvmfsClient::new(ByteSize(cap));
            for _ in 0..g.usize(1, 25) {
                let f = g.u64(0, 3);
                let size = (f + 1) * 3 * CVMFS_CHUNK;
                let off = g.u64(0, size - 1);
                let len = g.u64(0, size - off);
                let plan = c.plan_read(&format!("/f{f}"), off, len, size);
                c.commit_chunks(&format!("/f{f}"), 1, &plan.remote_chunks, None);
                if c.usage().as_u64() > cap {
                    return (false, format!("usage {} > cap {cap}", c.usage()));
                }
            }
            (true, String::new())
        });
    }
}
