//! Clients of the StashCache federation (paper §3.1).
//!
//! "Two clients are used to read from the StashCache federation. The
//! CERN Virtual Machine File System (CVMFS) and stashcp."
//!
//! * [`cvmfs`] — read-only POSIX interface: 24 MB chunked reads, a
//!   small (1 GB) local disk cache, partial-file reads, checksum
//!   verification against the indexer catalog.
//! * [`stashcp`] — the `cp`-like tool with its three-method fallback
//!   chain (CVMFS → XRootD → curl) and the GeoIP nearest-cache lookup
//!   that gives it its characteristic startup latency.
//! * [`curl`] — the plain HTTP client that downloads through the site
//!   forward proxy (the baseline of §4.1's comparison).

pub mod curl;
pub mod cvmfs;
pub mod stashcp;

use crate::util::Duration;

/// Transport a download ends up using.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// CVMFS POSIX read through a StashCache cache.
    Cvmfs,
    /// XRootD protocol directly against a StashCache cache.
    Xrootd,
    /// HTTP against a StashCache cache (stashcp's last resort).
    HttpCache,
    /// HTTP through the site forward proxy (the baseline; not part of
    /// stashcp's chain).
    HttpProxy,
    /// HTTP directly against the data origin — the federation's
    /// last-resort fallback when no cache (or proxy) can serve the
    /// transfer (failure injection / chaos scenarios).
    HttpOrigin,
}

/// What a finished download looked like (the unit of the §5 analysis).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransferRecord {
    pub path: String,
    pub bytes: u64,
    pub method: Method,
    /// Did the terminal server (cache or proxy) already hold the data?
    pub cache_hit: bool,
    pub duration: Duration,
}

impl TransferRecord {
    /// Average delivered rate in bytes/sec.
    pub fn rate_bps(&self) -> f64 {
        let secs = self.duration.as_secs_f64();
        if secs <= 0.0 {
            f64::INFINITY
        } else {
            self.bytes as f64 / secs
        }
    }

    /// Rate in Mbit/s (the unit of Figures 6-8).
    pub fn rate_mbps(&self) -> f64 {
        self.rate_bps() * 8.0 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_conversions() {
        let r = TransferRecord {
            path: "/f".into(),
            bytes: 1_000_000,
            method: Method::Cvmfs,
            cache_hit: true,
            duration: Duration::from_secs(2),
        };
        assert!((r.rate_bps() - 500_000.0).abs() < 1e-9);
        assert!((r.rate_mbps() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn zero_duration_is_infinite_rate() {
        let r = TransferRecord {
            path: "/f".into(),
            bytes: 1,
            method: Method::HttpProxy,
            cache_hit: true,
            duration: Duration::ZERO,
        };
        assert!(r.rate_bps().is_infinite());
    }
}
