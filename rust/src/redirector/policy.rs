//! Pluggable cache-selection policies: the redirection layer.
//!
//! The paper's clients pick a cache with one hardcoded rule — GeoIP
//! nearest (§3). Follow-on deployments generalised exactly this seam:
//! the XCache CDN work shards the namespace across caches so one file
//! converges on one cache, and the OSDF operations paper motivates
//! load-aware redirection from live cache telemetry. This module makes
//! the rule a first-class [`RedirectionPolicy`]:
//!
//! * [`Nearest`] — GeoIP distance + storage-load penalty, first
//!   reachable cache in rank order. **Bit-identical** to the legacy
//!   `FedSim::nearest_cache_site_filtered` ladder (regression-locked
//!   by `tests/redirection_policy.rs`).
//! * [`LeastLoaded`] — the `k` nearest reachable caches compete on
//!   *live* load: in-flight sessions first, then the cache WAN link's
//!   aggregate allocated rate, then geo rank. Spreads a burst across
//!   a region instead of piling onto one box.
//! * [`ConsistentHash`] — the namespace is sharded over a hash ring of
//!   cache sites with virtual nodes. Every client in the federation
//!   maps one path to one cache, so origin refetches collapse: a file
//!   requested at N sites is fetched from the origin once, not N
//!   times. Within one selection, excluded or down caches are holes
//!   in the ring — the walk continues to the next clockwise owner
//!   (the engine's `MAX_FAILOVER_RETRIES` ladder still bounds how
//!   many re-selections a session attempts).
//! * [`Tiered`] — site-local cache, else the nearest cache within a
//!   regional ring, else the origin: the generalisation of the
//!   failover ladder stashcp walks today, with the WAN tier opt-out
//!   that site operators actually configure.
//!
//! Policies are pure functions of a [`FederationView`] — an owned
//! snapshot of what the redirection layer may observe (geo ranking,
//! storage and live load, in-flight counts, up/down state). The view
//! is assembled by [`crate::federation::FedSim::federation_view`]; the
//! driver threads its per-cache in-flight counts in. Determinism: all
//! inputs are deterministic simulator state and every tie-break is
//! pinned (rank order, then cache-list order), so campaigns stay
//! bit-reproducible under every policy.

use crate::config::schema::RedirectionConfig;
use crate::util::fnv1a;

/// Which redirection policy a federation runs (config + sweep axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    Nearest,
    LeastLoaded,
    ConsistentHash,
    Tiered,
}

/// Every policy, in canonical order (CLI help, sweep presets, bench).
pub const ALL_POLICIES: [PolicyKind; 4] = [
    PolicyKind::Nearest,
    PolicyKind::LeastLoaded,
    PolicyKind::ConsistentHash,
    PolicyKind::Tiered,
];

/// The `a|b|c` list every "unknown policy" error shows. A test pins
/// it to [`ALL_POLICIES`], so adding a policy updates one file.
pub const POLICY_NAMES: &str = "nearest|least-loaded|consistent-hash|tiered";

impl PolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Nearest => "nearest",
            PolicyKind::LeastLoaded => "least-loaded",
            PolicyKind::ConsistentHash => "consistent-hash",
            PolicyKind::Tiered => "tiered",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "nearest" => Some(PolicyKind::Nearest),
            "least-loaded" => Some(PolicyKind::LeastLoaded),
            "consistent-hash" => Some(PolicyKind::ConsistentHash),
            "tiered" => Some(PolicyKind::Tiered),
            _ => None,
        }
    }
}

/// What the redirection layer may observe when placing one request:
/// an owned snapshot of the federation, indexed by *cache position*
/// (0..n in `geoip.caches()` order). `cache_sites[pos]` maps a
/// position back to the site index the rest of the simulator uses.
#[derive(Debug, Clone)]
pub struct FederationView {
    /// Site index of the requesting worker.
    pub client_site: usize,
    /// Cache site indices, in federation (geo database) order.
    pub cache_sites: Vec<usize>,
    /// Geo ranking: (position, score) best-first — distance plus the
    /// storage-load penalty, exactly the legacy GeoIP ordering (so the
    /// storage load is already folded in; no policy re-reads it).
    pub ranked: Vec<(usize, f64)>,
    /// Live aggregate allocated rate (bytes/s) on each cache's WAN
    /// access link — the netsim telemetry a load-aware redirector
    /// would scrape.
    pub wan_rate_bps: Vec<f64>,
    /// Sessions currently assigned to each cache by the engine
    /// driving this federation (all zero for serial drivers).
    pub in_flight: Vec<u64>,
    /// Great-circle km from the client site to each cache site.
    pub distance_km: Vec<f64>,
    /// Up/down per cache (the fault layer's view).
    pub up: Vec<bool>,
}

impl FederationView {
    /// May the cache at `pos` serve this request? (Up, and not one the
    /// session already failed against.)
    pub fn usable(&self, pos: usize, excluded: &[usize]) -> bool {
        self.up[pos] && !excluded.contains(&self.cache_sites[pos])
    }

    /// Position of a site's cache in the view, if that site hosts one.
    pub fn pos_of_site(&self, site: usize) -> Option<usize> {
        self.cache_sites.iter().position(|&s| s == site)
    }
}

/// A cache-selection rule. `select` returns the chosen cache *site
/// index*, or `None` when no cache should serve this request — the
/// caller then falls back to the origin (the tiered ladder's last
/// rung, shared by every policy when the federation is dark).
pub trait RedirectionPolicy: Send {
    fn kind(&self) -> PolicyKind;

    fn select(&self, path: &str, view: &FederationView, excluded: &[usize]) -> Option<usize>;

    /// Does `select` ignore the view's *live* fields (`in_flight`,
    /// `wan_rate_bps`)? A stable policy's choice is a pure function of
    /// the epoch-frozen federation (geo ranking, storage load, up/down
    /// state), so the sharded engine may snapshot one view per client
    /// site at an epoch boundary and reuse it for every selection in
    /// the epoch. Policies that read live telemetry must return
    /// `false` (the default) — the engine then keeps them on the
    /// serial path, where every selection sees fresh state.
    fn epoch_stable(&self) -> bool {
        false
    }
}

/// GeoIP nearest reachable cache — the paper's rule, bit-identical to
/// the legacy `nearest_cache_site_filtered` ladder.
#[derive(Debug, Clone, Copy, Default)]
pub struct Nearest;

impl RedirectionPolicy for Nearest {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Nearest
    }

    fn select(&self, _path: &str, view: &FederationView, excluded: &[usize]) -> Option<usize> {
        view.ranked
            .iter()
            .map(|&(pos, _)| pos)
            .find(|&pos| view.usable(pos, excluded))
            .map(|pos| view.cache_sites[pos])
    }

    fn epoch_stable(&self) -> bool {
        true
    }
}

/// The `k` nearest reachable caches compete on live load. Ordering:
/// fewest in-flight sessions, then lowest WAN aggregate rate, then
/// geo rank — every comparison strict, so ties keep the nearer cache
/// and selection is deterministic.
#[derive(Debug, Clone, Copy)]
pub struct LeastLoaded {
    /// How many nearest candidates compete (≥ 1).
    pub k: usize,
}

impl RedirectionPolicy for LeastLoaded {
    fn kind(&self) -> PolicyKind {
        PolicyKind::LeastLoaded
    }

    fn select(&self, _path: &str, view: &FederationView, excluded: &[usize]) -> Option<usize> {
        let mut best: Option<usize> = None;
        let mut best_key = (u64::MAX, f64::INFINITY);
        let mut considered = 0;
        for &(pos, _) in &view.ranked {
            if !view.usable(pos, excluded) {
                continue;
            }
            let key = (view.in_flight[pos], view.wan_rate_bps[pos]);
            let better = key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1);
            if best.is_none() || better {
                best = Some(pos);
                best_key = key;
            }
            considered += 1;
            if considered >= self.k {
                break;
            }
        }
        best.map(|pos| view.cache_sites[pos])
    }
}

/// Namespace sharding over a hash ring of cache sites with virtual
/// nodes: `hash(path)` lands on the ring and the first clockwise
/// owner serves it, so one file converges on one cache federation-wide
/// regardless of which site asks. Excluded and down caches are holes —
/// the walk continues to the next owner, which is how a failed cache's
/// shard redistributes without reshuffling anyone else's.
#[derive(Debug, Clone)]
pub struct ConsistentHash {
    /// (point, cache position), sorted by point then position.
    ring: Vec<(u64, usize)>,
}

impl ConsistentHash {
    /// Build the ring from the federation's cache-site names (the
    /// stable identity replicas hash under) with `virtual_nodes`
    /// points per cache for ring balance.
    pub fn new(cache_names: &[&str], virtual_nodes: usize) -> Self {
        let vnodes = virtual_nodes.max(1);
        let mut ring = Vec::with_capacity(cache_names.len() * vnodes);
        for (pos, name) in cache_names.iter().enumerate() {
            for v in 0..vnodes {
                let point = fnv1a(format!("{name}#{v}").as_bytes());
                ring.push((point, pos));
            }
        }
        // Hash collisions between distinct caches tie-break on
        // position, so the ring order is deterministic.
        ring.sort_unstable();
        ConsistentHash { ring }
    }

    /// Ring points (tests: balance + determinism).
    pub fn ring_len(&self) -> usize {
        self.ring.len()
    }
}

impl RedirectionPolicy for ConsistentHash {
    fn kind(&self) -> PolicyKind {
        PolicyKind::ConsistentHash
    }

    fn select(&self, path: &str, view: &FederationView, excluded: &[usize]) -> Option<usize> {
        if self.ring.is_empty() {
            return None;
        }
        let h = fnv1a(path.as_bytes());
        let start = self.ring.partition_point(|&(point, _)| point < h);
        for i in 0..self.ring.len() {
            let (_, pos) = self.ring[(start + i) % self.ring.len()];
            if pos < view.cache_sites.len() && view.usable(pos, excluded) {
                return Some(view.cache_sites[pos]);
            }
        }
        None
    }

    fn epoch_stable(&self) -> bool {
        true
    }
}

/// Site-local cache → nearest cache within `regional_km` → origin.
/// The ladder a site operator configures when WAN caches cost more
/// than they save: `None` here sends the session straight to the
/// origin instead of across an ocean.
#[derive(Debug, Clone, Copy)]
pub struct Tiered {
    /// Radius of the regional ring (km, > 0).
    pub regional_km: f64,
}

impl RedirectionPolicy for Tiered {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Tiered
    }

    fn select(&self, _path: &str, view: &FederationView, excluded: &[usize]) -> Option<usize> {
        // Tier 1: the client site's own cache.
        if let Some(pos) = view.pos_of_site(view.client_site) {
            if view.usable(pos, excluded) {
                return Some(view.cache_sites[pos]);
            }
        }
        // Tier 2: nearest usable cache inside the regional ring (rank
        // order, so the storage-load penalty still applies).
        for &(pos, _) in &view.ranked {
            if view.distance_km[pos] <= self.regional_km && view.usable(pos, excluded) {
                return Some(view.cache_sites[pos]);
            }
        }
        // Tier 3: no regional cache — stream from the origin.
        None
    }

    fn epoch_stable(&self) -> bool {
        true
    }
}

/// Instantiate the configured policy for a federation whose cache
/// sites are named `cache_names` (federation order — ring identity).
pub fn build_policy(cfg: &RedirectionConfig, cache_names: &[&str]) -> Box<dyn RedirectionPolicy> {
    match cfg.policy {
        PolicyKind::Nearest => Box::new(Nearest),
        PolicyKind::LeastLoaded => Box::new(LeastLoaded { k: cfg.nearest_k }),
        PolicyKind::ConsistentHash => {
            Box::new(ConsistentHash::new(cache_names, cfg.virtual_nodes))
        }
        PolicyKind::Tiered => Box::new(Tiered {
            regional_km: cfg.regional_km,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three caches: positions 0/1/2 at sites 10/20/30, ranked
    /// 0 (near) → 1 → 2 (far), client at site 99 (no local cache).
    fn view() -> FederationView {
        FederationView {
            client_site: 99,
            cache_sites: vec![10, 20, 30],
            ranked: vec![(0, 100.0), (1, 500.0), (2, 2500.0)],
            wan_rate_bps: vec![0.0, 0.0, 0.0],
            in_flight: vec![0, 0, 0],
            distance_km: vec![100.0, 500.0, 2500.0],
            up: vec![true, true, true],
        }
    }

    #[test]
    fn policy_names_round_trip() {
        for kind in ALL_POLICIES {
            assert_eq!(PolicyKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(PolicyKind::from_name("geo"), None);
        let joined: Vec<&str> = ALL_POLICIES.iter().map(|p| p.name()).collect();
        assert_eq!(POLICY_NAMES, joined.join("|"), "help list matches ALL_POLICIES");
    }

    #[test]
    fn nearest_walks_rank_order_with_holes() {
        let v = view();
        assert_eq!(Nearest.select("/f", &v, &[]), Some(10));
        assert_eq!(Nearest.select("/f", &v, &[10]), Some(20));
        let mut down = view();
        down.up[0] = false;
        assert_eq!(Nearest.select("/f", &down, &[20]), Some(30));
        assert_eq!(Nearest.select("/f", &down, &[20, 30]), None);
    }

    #[test]
    fn least_loaded_prefers_idle_within_k() {
        let mut v = view();
        v.in_flight = vec![5, 1, 0];
        // k=2: only positions 0 and 1 compete; 1 is idler.
        assert_eq!(LeastLoaded { k: 2 }.select("/f", &v, &[]), Some(20));
        // k=3 widens the pool to the idle far cache.
        assert_eq!(LeastLoaded { k: 3 }.select("/f", &v, &[]), Some(30));
        // k=1 degenerates to Nearest.
        assert_eq!(LeastLoaded { k: 1 }.select("/f", &v, &[]), Some(10));
    }

    #[test]
    fn least_loaded_ties_break_on_wan_rate_then_rank() {
        let mut v = view();
        v.in_flight = vec![2, 2, 2];
        v.wan_rate_bps = vec![9e9, 1e9, 1e9];
        // Equal sessions: lowest WAN rate wins; equal rate keeps the
        // nearer cache (position 1 beats 2).
        assert_eq!(LeastLoaded { k: 3 }.select("/f", &v, &[]), Some(20));
        // All equal ⇒ pure rank order.
        v.wan_rate_bps = vec![1e9, 1e9, 1e9];
        assert_eq!(LeastLoaded { k: 3 }.select("/f", &v, &[]), Some(10));
    }

    #[test]
    fn least_loaded_skips_unusable_before_counting_k() {
        let mut v = view();
        v.up[0] = false;
        v.in_flight = vec![0, 3, 0];
        // The dead cache is not a candidate: 1 and 2 compete, 2 idler.
        assert_eq!(LeastLoaded { k: 2 }.select("/f", &v, &[]), Some(30));
    }

    #[test]
    fn consistent_hash_is_client_independent_and_total() {
        let ch = ConsistentHash::new(&["a", "b", "c"], 64);
        assert_eq!(ch.ring_len(), 3 * 64);
        let near = view();
        let mut far = view();
        far.client_site = 7;
        far.ranked = vec![(2, 1.0), (1, 2.0), (0, 3.0)]; // reversed rank
        for i in 0..50 {
            let path = format!("/ospool/x/data/f{i:06}.dat");
            let a = ch.select(&path, &near, &[]);
            let b = ch.select(&path, &far, &[]);
            assert!(a.is_some(), "ring covers every path");
            assert_eq!(a, b, "placement must not depend on the client");
        }
    }

    #[test]
    fn consistent_hash_ring_spreads_over_caches() {
        let ch = ConsistentHash::new(&["a", "b", "c"], 64);
        let v = view();
        let mut hits = [0usize; 3];
        for i in 0..300 {
            let path = format!("/ospool/x/data/f{i:06}.dat");
            let site = ch.select(&path, &v, &[]).unwrap();
            hits[v.cache_sites.iter().position(|&s| s == site).unwrap()] += 1;
        }
        for (pos, &n) in hits.iter().enumerate() {
            assert!(n > 0, "cache {pos} owns no shard of 300 paths");
        }
    }

    #[test]
    fn consistent_hash_excluded_is_a_ring_hole() {
        let ch = ConsistentHash::new(&["a", "b", "c"], 64);
        let v = view();
        let path = "/ospool/x/data/f000001.dat";
        let owner = ch.select(path, &v, &[]).unwrap();
        let next = ch.select(path, &v, &[owner]).unwrap();
        assert_ne!(owner, next, "hole walks to the next owner");
        // Same hole via the fault layer.
        let mut down = view();
        let owner_pos = down.cache_sites.iter().position(|&s| s == owner).unwrap();
        down.up[owner_pos] = false;
        assert_eq!(ch.select(path, &down, &[]), Some(next));
        // Every cache gone ⇒ origin fallback.
        assert_eq!(ch.select(path, &v, &[10, 20, 30]), None);
    }

    #[test]
    fn consistent_hash_is_deterministic_across_builds() {
        let a = ConsistentHash::new(&["a", "b", "c"], 32);
        let b = ConsistentHash::new(&["a", "b", "c"], 32);
        let v = view();
        for i in 0..40 {
            let path = format!("/p/{i}");
            assert_eq!(a.select(&path, &v, &[]), b.select(&path, &v, &[]));
        }
    }

    #[test]
    fn tiered_ladder_local_then_regional_then_origin() {
        let t = Tiered { regional_km: 600.0 };
        // Client hosts cache site 20 (position 1): tier 1.
        let mut v = view();
        v.client_site = 20;
        assert_eq!(t.select("/f", &v, &[]), Some(20));
        // Local excluded: regional ring (0 and 1 are within 600 km).
        assert_eq!(t.select("/f", &v, &[20]), Some(10));
        // No local cache: nearest regional.
        let v = view();
        assert_eq!(t.select("/f", &v, &[]), Some(10));
        // Regional ring exhausted ⇒ origin, never the 2500 km cache.
        assert_eq!(t.select("/f", &v, &[10, 20]), None);
        let tight = Tiered { regional_km: 50.0 };
        assert_eq!(tight.select("/f", &v, &[]), None);
    }

    #[test]
    fn epoch_stability_matches_live_telemetry_use() {
        // Stable = selection ignores in_flight / wan_rate_bps; flipping
        // the live fields must not change the choice.
        assert!(Nearest.epoch_stable());
        assert!(ConsistentHash::new(&["a", "b", "c"], 8).epoch_stable());
        assert!(Tiered { regional_km: 600.0 }.epoch_stable());
        assert!(!LeastLoaded { k: 2 }.epoch_stable());
        let mut busy = view();
        busy.in_flight = vec![900, 1, 1];
        busy.wan_rate_bps = vec![9e9, 0.0, 0.0];
        assert_eq!(Nearest.select("/f", &busy, &[]), Nearest.select("/f", &view(), &[]));
        assert_ne!(
            LeastLoaded { k: 3 }.select("/f", &busy, &[]),
            LeastLoaded { k: 3 }.select("/f", &view(), &[])
        );
    }

    #[test]
    fn build_policy_matches_kind() {
        let mut cfg = RedirectionConfig::default();
        for kind in ALL_POLICIES {
            cfg.policy = kind;
            assert_eq!(build_policy(&cfg, &["a", "b"]).kind(), kind);
        }
    }
}
