//! Per-cache circuit breaker: the redirection layer's gray-failure
//! defence.
//!
//! A binary outage ([`crate::fault::FaultKind::CacheDown`]) is easy —
//! the fault state ejects the cache from every candidate set. A *gray*
//! failure (a 20×-slow cache, silent corruption) leaves the cache
//! nominally up, so the redirector keeps routing clients at it and
//! each one pays a transfer deadline before failing over. The breaker
//! closes that loop: every session outcome at a cache (successful
//! serve vs timeout / corruption / abort) feeds an EWMA health score,
//! and when the score trips the threshold the cache is ejected from
//! [`super::policy::FederationView`] candidate sets exactly like a
//! dead one — composing with all four [`super::policy::RedirectionPolicy`]
//! impls, which already consult the view's `up` vector.
//!
//! State machine (classic three-state, collapsed to two reps):
//!
//! ```text
//!         score >= threshold
//! Closed ────────────────────▶ Open { until = now + cooldown }
//!    ▲                            │
//!    │ probe success              │ now >= until: admits again
//!    │ (score resets)             ▼ ("half-open" window)
//!    └──────────────────────── HalfOpen ──▶ probe failure re-arms
//!                                           Open (fresh cooldown)
//! ```
//!
//! Everything is driven by the engine's virtual clock and the
//! deterministic outcome stream, so breaker transitions are
//! reproducible run-to-run — and an armed breaker keeps the sharded
//! engine serial (see the epoch-stability gate in
//! [`crate::federation::driver`]), preserving thread-count digest
//! equality.

use crate::config::ResilienceConfig;
use crate::util::{Duration, SimTime};
use std::collections::BTreeMap;

/// What a finished (or abandoned) cache interaction tells the breaker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerOutcome {
    /// The cache served the transfer to completion.
    Success,
    /// The session's transfer deadline expired at this cache.
    Timeout,
    /// The client's digest check caught corrupted bytes.
    Corruption,
    /// The transfer died under the session (fault-driven abort).
    Abort,
}

impl BreakerOutcome {
    /// EWMA failure indicator: 1 for any failure mode, 0 for success.
    fn failure(self) -> f64 {
        match self {
            BreakerOutcome::Success => 0.0,
            _ => 1.0,
        }
    }
}

/// Health ledger of one cache site.
#[derive(Debug, Clone, Copy, PartialEq)]
struct CacheHealth {
    /// EWMA of failure indicators: 0 = healthy, 1 = every recent
    /// interaction failed.
    score: f64,
    /// `Some(until)`: tripped open, ejected from candidate sets until
    /// the cooldown elapses; past `until` the breaker is half-open and
    /// admits probe sessions. `None`: closed.
    open_until: Option<SimTime>,
}

impl CacheHealth {
    const CLOSED: CacheHealth = CacheHealth {
        score: 0.0,
        open_until: None,
    };
}

/// Per-cache health scores + trip state for the whole federation.
/// Lives on [`crate::federation::FedSim`] as `Option<CacheBreaker>`
/// (`None` = breaker off = zero behavioral change).
#[derive(Debug, Clone)]
pub struct CacheBreaker {
    alpha: f64,
    threshold: f64,
    cooldown: Duration,
    /// cache site → health (absent = pristine closed).
    states: BTreeMap<usize, CacheHealth>,
    /// Closed → open transitions.
    pub trips: u64,
    /// Half-open probe failures (open re-armed).
    pub reopens: u64,
    /// Half-open probe successes (breaker closed again).
    pub recoveries: u64,
}

impl CacheBreaker {
    pub fn new(cfg: &ResilienceConfig) -> Self {
        cfg.validate().expect("valid resilience config");
        CacheBreaker {
            alpha: cfg.breaker_alpha,
            threshold: cfg.breaker_threshold,
            cooldown: Duration::from_secs_f64(cfg.breaker_cooldown_secs),
            states: BTreeMap::new(),
            trips: 0,
            reopens: 0,
            recoveries: 0,
        }
    }

    /// May the redirection layer hand `site` to a client at `now`?
    /// Closed ⇒ yes; open ⇒ only once the cooldown has elapsed (the
    /// half-open window, which admits the probe).
    pub fn admits(&self, site: usize, now: SimTime) -> bool {
        match self.states.get(&site) {
            None => true,
            Some(h) => match h.open_until {
                None => true,
                Some(until) => now >= until,
            },
        }
    }

    /// Is the breaker open (still cooling down) for `site` at `now`?
    pub fn is_open(&self, site: usize, now: SimTime) -> bool {
        !self.admits(site, now)
    }

    /// Caches currently ejected from candidate sets.
    pub fn open_count(&self, now: SimTime) -> usize {
        self.states
            .keys()
            .filter(|&&site| self.is_open(site, now))
            .count()
    }

    /// Fold one session outcome at `site` into its health score and
    /// walk the state machine. Called by the engine on every cache
    /// serve completion, deadline expiry, corruption detection, and
    /// fault-driven abort.
    pub fn record(&mut self, site: usize, outcome: BreakerOutcome, now: SimTime) {
        let h = self.states.entry(site).or_insert(CacheHealth::CLOSED);
        h.score = (1.0 - self.alpha) * h.score + self.alpha * outcome.failure();
        match h.open_until {
            None => {
                if h.score >= self.threshold {
                    h.open_until = Some(now + self.cooldown);
                    self.trips += 1;
                }
            }
            Some(until) if now >= until => {
                // Half-open: this outcome is the probe's verdict.
                if outcome == BreakerOutcome::Success {
                    *h = CacheHealth::CLOSED;
                    self.recoveries += 1;
                } else {
                    h.open_until = Some(now + self.cooldown);
                    self.reopens += 1;
                }
            }
            // Straggler outcome from a transfer that began before the
            // trip: folded into the score above, but the cooldown
            // clock is not restarted.
            Some(_) => {}
        }
    }

    /// Deterministic state dump, sorted by site — the model checker
    /// hashes this so interleavings that diverge only in breaker state
    /// are distinct states. `(site, score bits, open-until micros or
    /// MAX for closed)`.
    pub fn fingerprint(&self) -> Vec<(usize, u64, u64)> {
        self.states
            .iter()
            .map(|(&site, h)| {
                (
                    site,
                    h.score.to_bits(),
                    h.open_until.map_or(u64::MAX, |t| t.as_micros()),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ResilienceConfig {
        ResilienceConfig {
            breaker: true,
            breaker_alpha: 0.5,
            breaker_threshold: 0.6,
            breaker_cooldown_secs: 10.0,
            ..ResilienceConfig::default()
        }
    }

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn pristine_cache_is_admitted() {
        let b = CacheBreaker::new(&cfg());
        assert!(b.admits(3, SimTime::ZERO));
        assert_eq!(b.open_count(SimTime::ZERO), 0);
    }

    #[test]
    fn trips_after_repeated_failures_not_one() {
        let mut b = CacheBreaker::new(&cfg());
        b.record(0, BreakerOutcome::Timeout, t(1.0));
        assert!(b.admits(0, t(1.0)), "one failure (score 0.5) stays closed");
        b.record(0, BreakerOutcome::Timeout, t(2.0));
        assert!(!b.admits(0, t(2.0)), "score 0.75 trips the 0.6 threshold");
        assert_eq!(b.trips, 1);
        // Other caches are untouched.
        assert!(b.admits(1, t(2.0)));
    }

    #[test]
    fn successes_decay_the_score() {
        let mut b = CacheBreaker::new(&cfg());
        b.record(0, BreakerOutcome::Timeout, t(1.0));
        b.record(0, BreakerOutcome::Success, t(2.0));
        b.record(0, BreakerOutcome::Timeout, t(3.0));
        // 0.5 → 0.25 → 0.625: trips only because the last failure
        // pushed it back over; a healthy mix stays below.
        assert_eq!(b.trips, 1);
        let mut healthy = CacheBreaker::new(&cfg());
        for i in 0..10 {
            healthy.record(0, BreakerOutcome::Success, t(i as f64));
            healthy.record(0, BreakerOutcome::Timeout, t(i as f64 + 0.5));
            healthy.record(0, BreakerOutcome::Success, t(i as f64 + 0.7));
        }
        assert_eq!(healthy.trips, 0, "1-in-3 failures never crosses 0.6");
    }

    #[test]
    fn open_breaker_admits_again_after_cooldown() {
        let mut b = CacheBreaker::new(&cfg());
        b.record(0, BreakerOutcome::Timeout, t(1.0));
        b.record(0, BreakerOutcome::Timeout, t(2.0));
        assert!(b.is_open(0, t(5.0)), "cooling down");
        assert!(b.admits(0, t(12.0)), "half-open at until = 2 + 10");
    }

    #[test]
    fn half_open_probe_success_closes_and_resets() {
        let mut b = CacheBreaker::new(&cfg());
        b.record(0, BreakerOutcome::Timeout, t(1.0));
        b.record(0, BreakerOutcome::Timeout, t(2.0));
        b.record(0, BreakerOutcome::Success, t(13.0));
        assert_eq!(b.recoveries, 1);
        assert!(b.admits(0, t(13.0)));
        // Score reset: one subsequent failure does not re-trip.
        b.record(0, BreakerOutcome::Timeout, t(14.0));
        assert!(b.admits(0, t(14.0)));
    }

    #[test]
    fn half_open_probe_failure_rearms_the_cooldown() {
        let mut b = CacheBreaker::new(&cfg());
        b.record(0, BreakerOutcome::Timeout, t(1.0));
        b.record(0, BreakerOutcome::Corruption, t(2.0));
        b.record(0, BreakerOutcome::Timeout, t(13.0));
        assert_eq!(b.reopens, 1);
        assert!(b.is_open(0, t(20.0)), "fresh cooldown from t=13");
        assert!(b.admits(0, t(23.0)));
    }

    #[test]
    fn straggler_outcome_during_cooldown_does_not_restart_clock() {
        let mut b = CacheBreaker::new(&cfg());
        b.record(0, BreakerOutcome::Timeout, t(1.0));
        b.record(0, BreakerOutcome::Abort, t(2.0));
        // A transfer that started pre-trip fails at t=5, mid-cooldown.
        b.record(0, BreakerOutcome::Abort, t(5.0));
        assert_eq!(b.reopens, 0, "not a probe verdict");
        assert!(b.admits(0, t(12.0)), "original until = 2 + 10 stands");
    }

    /// The satellite's property test: however the breaker got tripped,
    /// a successful half-open probe always re-admits the cache.
    #[test]
    fn tripped_breaker_always_readmits_after_probe_success() {
        let failures = [
            BreakerOutcome::Timeout,
            BreakerOutcome::Corruption,
            BreakerOutcome::Abort,
        ];
        // Sweep trip histories: every failure-kind pair, varying run
        // lengths, across alpha/threshold settings.
        for &a in &failures {
            for &b_kind in &failures {
                for run in 2..6u32 {
                    for (alpha, threshold) in [(0.3, 0.5), (0.5, 0.6), (0.9, 0.2)] {
                        let rc = ResilienceConfig {
                            breaker: true,
                            breaker_alpha: alpha,
                            breaker_threshold: threshold,
                            breaker_cooldown_secs: 10.0,
                            ..ResilienceConfig::default()
                        };
                        let mut b = CacheBreaker::new(&rc);
                        for i in 0..run {
                            let kind = if i % 2 == 0 { a } else { b_kind };
                            b.record(7, kind, t(f64::from(i)));
                        }
                        if !b.is_open(7, t(f64::from(run))) {
                            continue; // this history never tripped
                        }
                        // Wait out the cooldown, land the probe.
                        let probe_at = t(f64::from(run) + 10.0);
                        assert!(b.admits(7, probe_at), "half-open admits the probe");
                        b.record(7, BreakerOutcome::Success, probe_at);
                        assert!(
                            b.admits(7, probe_at),
                            "probe success must re-admit (α={alpha}, θ={threshold}, run={run})"
                        );
                        assert!(b.recoveries >= 1);
                    }
                }
            }
        }
    }

    #[test]
    fn fingerprint_is_sorted_and_content_sensitive() {
        let mut b = CacheBreaker::new(&cfg());
        b.record(5, BreakerOutcome::Timeout, t(1.0));
        b.record(2, BreakerOutcome::Success, t(2.0));
        let fp = b.fingerprint();
        assert_eq!(fp.len(), 2);
        assert!(fp[0].0 < fp[1].0, "sorted by site");
        let before = fp.clone();
        b.record(2, BreakerOutcome::Timeout, t(3.0));
        assert_ne!(b.fingerprint(), before);
    }
}
