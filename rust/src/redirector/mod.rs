//! XRootD-style redirector: the federation's data-discovery service.
//!
//! Paper §3: "The redirector serves as the data discovery service.
//! Caches query the redirector to find which origin contains the
//! requested data. The redirector will query the origins in order to
//! find the data and return the hostname of the origin ... There are
//! two redirectors in a round robin, high availability configuration."
//!
//! [`Redirector`] holds a TTL'd, LRU-bounded location cache and
//! broadcasts to the origin set on a miss (cmsd-style). Entries are
//! valid *through* their expiry instant and stale one microsecond
//! after — the same freshness rule the site proxy uses — and the
//! cache never exceeds `cache_cap` entries: inserting into a full
//! cache evicts the least-recently-used location (`evictions` counts
//! them), so months-long campaigns cannot grow it without bound.
//! [`RedirectorPool`] provides the round-robin HA front: lookups
//! rotate across healthy instances and fail over when an instance is
//! marked down (failure injection uses this in the integration tests).
//!
//! Cache *selection* — which cache a client is redirected to — is the
//! pluggable [`policy`] layer ([`policy::RedirectionPolicy`]).

pub mod breaker;
pub mod policy;

pub use breaker::{BreakerOutcome, CacheBreaker};
pub use policy::{FederationView, PolicyKind, RedirectionPolicy, ALL_POLICIES, POLICY_NAMES};

use crate::namespace::OriginId;
use crate::origin::Origin;
use crate::util::{Duration, SimTime};
use std::collections::{BTreeMap, HashMap};

/// Default bound on a redirector's location cache (entries). Exposed
/// through `[redirection] location_cache_cap` in the federation TOML.
pub const DEFAULT_LOCATION_CACHE_CAP: usize = 65_536;

/// One redirector instance.
#[derive(Debug)]
pub struct Redirector {
    pub id: usize,
    /// path → (origin, cache-entry expiry, recency sequence).
    location_cache: HashMap<String, (OriginId, SimTime, u64)>,
    /// Recency sequence → path; the smallest key is the LRU victim.
    lru: BTreeMap<u64, String>,
    /// Monotone recency counter (bumped on hit and insert).
    next_seq: u64,
    /// Max location-cache entries before LRU eviction (≥ 1).
    pub cache_cap: usize,
    /// TTL of location-cache entries.
    pub cache_ttl: Duration,
    /// Instance up? (failure injection)
    pub healthy: bool,
    pub queries: u64,
    pub cache_hits: u64,
    /// Origin broadcasts performed (each asks every origin).
    pub broadcasts: u64,
    /// Entries evicted by the LRU cap (not TTL expiry).
    pub evictions: u64,
}

impl Redirector {
    pub fn new(id: usize) -> Self {
        Self::with_cap(id, DEFAULT_LOCATION_CACHE_CAP)
    }

    /// An instance whose location cache holds at most `cap` entries.
    pub fn with_cap(id: usize, cap: usize) -> Self {
        assert!(cap >= 1, "location cache cap must be >= 1");
        Redirector {
            id,
            location_cache: HashMap::new(),
            lru: BTreeMap::new(),
            next_seq: 0,
            cache_cap: cap,
            cache_ttl: Duration::from_mins(10),
            healthy: true,
            queries: 0,
            cache_hits: 0,
            broadcasts: 0,
            evictions: 0,
        }
    }

    /// Resolve `path` to an origin, consulting the location cache and
    /// otherwise broadcasting to all origins ("the redirector will
    /// query the origins").
    pub fn locate(
        &mut self,
        path: &str,
        origins: &mut [Origin],
        now: SimTime,
    ) -> Option<OriginId> {
        self.queries += 1;
        if let Some(&(origin, expires, seq)) = self.location_cache.get(path) {
            // Valid through the expiry instant, stale 1 µs past it
            // (mirrors the proxy's freshness rule).
            if now <= expires {
                self.cache_hits += 1;
                self.touch(path, seq);
                return Some(origin);
            }
            self.location_cache.remove(path);
            self.lru.remove(&seq);
        }
        self.broadcasts += 1;
        for o in origins.iter_mut() {
            if o.locate(path) {
                self.insert(path, o.id, now + self.cache_ttl);
                return Some(o.id);
            }
        }
        None
    }

    /// Refresh an entry's recency (LRU hit promotion). Updates the
    /// seq in place — the hit path pays one `String` for the LRU map,
    /// not a remove+insert cycle on the location cache.
    fn touch(&mut self, path: &str, old_seq: u64) {
        self.lru.remove(&old_seq);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.lru.insert(seq, path.to_string());
        if let Some(entry) = self.location_cache.get_mut(path) {
            entry.2 = seq;
        }
    }

    /// Insert a fresh location, evicting LRU entries past the cap.
    fn insert(&mut self, path: &str, origin: OriginId, expires: SimTime) {
        if let Some((_, _, old_seq)) = self.location_cache.remove(path) {
            self.lru.remove(&old_seq);
        }
        while self.location_cache.len() >= self.cache_cap {
            let victim_seq = *self.lru.keys().next().expect("cap >= 1, cache full");
            let victim = self.lru.remove(&victim_seq).expect("lru entry");
            self.location_cache.remove(&victim);
            self.evictions += 1;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.lru.insert(seq, path.to_string());
        self.location_cache
            .insert(path.to_string(), (origin, expires, seq));
    }

    /// Drop a cached location (e.g. after an origin deletion event).
    pub fn invalidate(&mut self, path: &str) {
        if let Some((_, _, seq)) = self.location_cache.remove(path) {
            self.lru.remove(&seq);
        }
    }

    pub fn cached_locations(&self) -> usize {
        self.location_cache.len()
    }
}

/// Outcome of a pool lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LocateOutcome {
    pub origin: OriginId,
    /// Which instance answered.
    pub instance: usize,
    /// Instances tried (1 unless failover happened).
    pub attempts: usize,
}

/// Round-robin HA pool of redirectors (the OSG runs two — §3).
#[derive(Debug)]
pub struct RedirectorPool {
    pub instances: Vec<Redirector>,
    rr: usize,
}

/// Error when every instance is down.
#[derive(Debug, PartialEq)]
pub struct AllRedirectorsDown(pub usize);

impl std::fmt::Display for AllRedirectorsDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "all {} redirector instances are down", self.0)
    }
}

impl std::error::Error for AllRedirectorsDown {}

impl RedirectorPool {
    pub fn new(count: usize) -> Self {
        Self::with_cap(count, DEFAULT_LOCATION_CACHE_CAP)
    }

    /// A pool whose instances cap their location caches at `cap`.
    pub fn with_cap(count: usize, cap: usize) -> Self {
        assert!(count >= 1);
        RedirectorPool {
            instances: (0..count).map(|id| Redirector::with_cap(id, cap)).collect(),
            rr: 0,
        }
    }

    /// Round-robin locate with failover across unhealthy instances.
    /// Returns `Ok(None)` when the path exists nowhere (a healthy
    /// instance answered "not found").
    pub fn locate(
        &mut self,
        path: &str,
        origins: &mut [Origin],
        now: SimTime,
    ) -> Result<Option<LocateOutcome>, AllRedirectorsDown> {
        let n = self.instances.len();
        for attempt in 0..n {
            let idx = (self.rr + attempt) % n;
            if !self.instances[idx].healthy {
                continue;
            }
            self.rr = (idx + 1) % n; // next query starts after the responder
            let found = self.instances[idx].locate(path, origins, now);
            return Ok(found.map(|origin| LocateOutcome {
                origin,
                instance: idx,
                attempts: attempt + 1,
            }));
        }
        Err(AllRedirectorsDown(n))
    }

    /// Mark an instance down/up (failure injection).
    pub fn set_healthy(&mut self, instance: usize, healthy: bool) {
        self.instances[instance].healthy = healthy;
    }

    /// Instances currently answering (the availability report's view
    /// of the HA pair).
    pub fn healthy_count(&self) -> usize {
        self.instances.iter().filter(|r| r.healthy).count()
    }

    pub fn total_queries(&self) -> u64 {
        self.instances.iter().map(|r| r.queries).sum()
    }

    /// Location-cache LRU evictions across the pool (stats).
    pub fn total_evictions(&self) -> u64 {
        self.instances.iter().map(|r| r.evictions).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::origin::FileMeta;

    fn origins() -> Vec<Origin> {
        let mut o1 = Origin::new(OriginId(0), "o-ligo", "/ospool/ligo");
        o1.put_file("/ospool/ligo/f1", FileMeta { size: 10, mtime: 1, perm: 0o644 })
            .unwrap();
        let mut o2 = Origin::new(OriginId(1), "o-des", "/ospool/des");
        o2.put_file("/ospool/des/d1", FileMeta { size: 20, mtime: 1, perm: 0o644 })
            .unwrap();
        vec![o1, o2]
    }

    /// Origins with `n` files under /ospool/ligo (LRU cap tests).
    fn origin_with_files(n: usize) -> Vec<Origin> {
        let mut o = Origin::new(OriginId(0), "o-ligo", "/ospool/ligo");
        for i in 0..n {
            o.put_file(
                &format!("/ospool/ligo/f{i}"),
                FileMeta { size: 10, mtime: 1, perm: 0o644 },
            )
            .unwrap();
        }
        vec![o]
    }

    #[test]
    fn locates_correct_origin() {
        let mut os = origins();
        let mut r = Redirector::new(0);
        assert_eq!(
            r.locate("/ospool/des/d1", &mut os, SimTime::ZERO),
            Some(OriginId(1))
        );
        assert_eq!(r.locate("/nope", &mut os, SimTime::ZERO), None);
    }

    #[test]
    fn location_cache_avoids_rebroadcast() {
        let mut os = origins();
        let mut r = Redirector::new(0);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        let broadcasts_before = r.broadcasts;
        let queries_to_origins = os[0].locate_queries + os[1].locate_queries;
        r.locate("/ospool/ligo/f1", &mut os, SimTime::from_secs_f64(1.0));
        assert_eq!(r.broadcasts, broadcasts_before, "cache hit, no broadcast");
        assert_eq!(os[0].locate_queries + os[1].locate_queries, queries_to_origins);
        assert_eq!(r.cache_hits, 1);
    }

    #[test]
    fn location_cache_expires() {
        let mut os = origins();
        let mut r = Redirector::new(0);
        r.cache_ttl = Duration::from_secs(60);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::from_secs_f64(120.0));
        assert_eq!(r.broadcasts, 2, "expired entry re-broadcasts");
    }

    #[test]
    fn ttl_edge_hit_at_expiry_stale_one_microsecond_past() {
        // Mirrors the proxy's expiry edge: an entry cached at t=0 with
        // a 60 s TTL serves *through* t=60 s and re-broadcasts at
        // t=60 s + 1 µs.
        let mut os = origins();
        let mut r = Redirector::new(0);
        r.cache_ttl = Duration::from_secs(60);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        assert_eq!(r.broadcasts, 1);

        let at_ttl = SimTime::ZERO + Duration::from_secs(60);
        assert_eq!(
            r.locate("/ospool/ligo/f1", &mut os, at_ttl),
            Some(OriginId(0))
        );
        assert_eq!(r.broadcasts, 1, "age == ttl still serves from cache");
        assert_eq!(r.cache_hits, 1);

        let past_ttl = at_ttl + Duration::from_micros(1);
        assert_eq!(
            r.locate("/ospool/ligo/f1", &mut os, past_ttl),
            Some(OriginId(0))
        );
        assert_eq!(r.broadcasts, 2, "1 µs past the ttl re-broadcasts");
        // The re-broadcast re-armed the entry: fresh again afterwards.
        r.locate("/ospool/ligo/f1", &mut os, past_ttl + Duration::from_secs(1));
        assert_eq!(r.broadcasts, 2);
        assert_eq!(r.cache_hits, 2);
    }

    #[test]
    fn lru_cap_bounds_cache_and_counts_evictions() {
        let mut os = origin_with_files(3);
        let mut r = Redirector::with_cap(0, 2);
        r.locate("/ospool/ligo/f0", &mut os, SimTime::ZERO);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        assert_eq!(r.cached_locations(), 2);
        assert_eq!(r.evictions, 0);
        // Third insert evicts the coldest entry (f0).
        r.locate("/ospool/ligo/f2", &mut os, SimTime::ZERO);
        assert_eq!(r.cached_locations(), 2, "cap holds");
        assert_eq!(r.evictions, 1);
        let broadcasts = r.broadcasts;
        // f1 and f2 are still cached; f0 was evicted and re-broadcasts.
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        r.locate("/ospool/ligo/f2", &mut os, SimTime::ZERO);
        assert_eq!(r.broadcasts, broadcasts);
        r.locate("/ospool/ligo/f0", &mut os, SimTime::ZERO);
        assert_eq!(r.broadcasts, broadcasts + 1, "evicted entry re-broadcasts");
    }

    #[test]
    fn lru_hit_promotes_entry() {
        let mut os = origin_with_files(3);
        let mut r = Redirector::with_cap(0, 2);
        r.locate("/ospool/ligo/f0", &mut os, SimTime::ZERO);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        // Touch f0: f1 becomes the LRU victim.
        r.locate("/ospool/ligo/f0", &mut os, SimTime::from_secs_f64(1.0));
        r.locate("/ospool/ligo/f2", &mut os, SimTime::from_secs_f64(2.0));
        let broadcasts = r.broadcasts;
        r.locate("/ospool/ligo/f0", &mut os, SimTime::from_secs_f64(3.0));
        assert_eq!(r.broadcasts, broadcasts, "promoted entry survived");
        r.locate("/ospool/ligo/f1", &mut os, SimTime::from_secs_f64(4.0));
        assert_eq!(r.broadcasts, broadcasts + 1, "victim was the cold f1");
    }

    #[test]
    fn pool_round_robins() {
        let mut os = origins();
        let mut pool = RedirectorPool::new(2);
        let a = pool
            .locate("/ospool/ligo/f1", &mut os, SimTime::ZERO)
            .unwrap()
            .unwrap();
        let b = pool
            .locate("/ospool/des/d1", &mut os, SimTime::ZERO)
            .unwrap()
            .unwrap();
        assert_ne!(a.instance, b.instance, "round robin alternates");
    }

    #[test]
    fn pool_fails_over() {
        let mut os = origins();
        let mut pool = RedirectorPool::new(2);
        pool.set_healthy(0, false);
        for _ in 0..3 {
            let out = pool
                .locate("/ospool/ligo/f1", &mut os, SimTime::ZERO)
                .unwrap()
                .unwrap();
            assert_eq!(out.instance, 1);
        }
    }

    #[test]
    fn pool_rotation_skips_unhealthy_and_resumes_fair() {
        let mut os = origins();
        let mut pool = RedirectorPool::with_cap(3, DEFAULT_LOCATION_CACHE_CAP);
        let answer = |pool: &mut RedirectorPool, os: &mut Vec<Origin>| {
            pool.locate("/ospool/ligo/f1", os, SimTime::ZERO)
                .unwrap()
                .unwrap()
                .instance
        };
        // Healthy warm-up: 0, 1, 2.
        assert_eq!(
            [answer(&mut pool, &mut os), answer(&mut pool, &mut os), answer(&mut pool, &mut os)],
            [0, 1, 2]
        );
        // Instance 1 down: rotation skips it and alternates 0/2.
        pool.set_healthy(1, false);
        let while_down: Vec<usize> = (0..4).map(|_| answer(&mut pool, &mut os)).collect();
        assert_eq!(while_down, vec![0, 2, 0, 2]);
        assert!(!while_down.contains(&1), "down instance never answers");
        // Recovery: over the next two full cycles every instance
        // answers exactly twice — rotation is fair again.
        pool.set_healthy(1, true);
        let mut counts = [0usize; 3];
        for _ in 0..6 {
            counts[answer(&mut pool, &mut os)] += 1;
        }
        assert_eq!(counts, [2, 2, 2], "fair rotation after recovery");
    }

    #[test]
    fn pool_all_down_errors() {
        let mut os = origins();
        let mut pool = RedirectorPool::new(2);
        pool.set_healthy(0, false);
        pool.set_healthy(1, false);
        assert_eq!(
            pool.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO),
            Err(AllRedirectorsDown(2))
        );
        // Recovery restores service.
        pool.set_healthy(1, true);
        assert!(pool
            .locate("/ospool/ligo/f1", &mut os, SimTime::ZERO)
            .unwrap()
            .is_some());
    }

    #[test]
    fn invalidate_forces_rebroadcast() {
        let mut os = origins();
        let mut r = Redirector::new(0);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        r.invalidate("/ospool/ligo/f1");
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        assert_eq!(r.broadcasts, 2);
        assert_eq!(r.cached_locations(), 1);
    }
}
