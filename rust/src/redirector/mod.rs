//! XRootD-style redirector: the federation's data-discovery service.
//!
//! Paper §3: "The redirector serves as the data discovery service.
//! Caches query the redirector to find which origin contains the
//! requested data. The redirector will query the origins in order to
//! find the data and return the hostname of the origin ... There are
//! two redirectors in a round robin, high availability configuration."
//!
//! [`Redirector`] holds a TTL'd location cache and broadcasts to the
//! origin set on a miss (cmsd-style). [`RedirectorPool`] provides the
//! round-robin HA front: lookups rotate across healthy instances and
//! fail over when an instance is marked down (failure injection uses
//! this in the integration tests).

use crate::namespace::OriginId;
use crate::origin::Origin;
use crate::util::{Duration, SimTime};
use std::collections::HashMap;

/// One redirector instance.
#[derive(Debug)]
pub struct Redirector {
    pub id: usize,
    /// path → (origin, cache-entry expiry).
    location_cache: HashMap<String, (OriginId, SimTime)>,
    /// TTL of location-cache entries.
    pub cache_ttl: Duration,
    /// Instance up? (failure injection)
    pub healthy: bool,
    pub queries: u64,
    pub cache_hits: u64,
    /// Origin broadcasts performed (each asks every origin).
    pub broadcasts: u64,
}

impl Redirector {
    pub fn new(id: usize) -> Self {
        Redirector {
            id,
            location_cache: HashMap::new(),
            cache_ttl: Duration::from_mins(10),
            healthy: true,
            queries: 0,
            cache_hits: 0,
            broadcasts: 0,
        }
    }

    /// Resolve `path` to an origin, consulting the location cache and
    /// otherwise broadcasting to all origins ("the redirector will
    /// query the origins").
    pub fn locate(
        &mut self,
        path: &str,
        origins: &mut [Origin],
        now: SimTime,
    ) -> Option<OriginId> {
        self.queries += 1;
        if let Some(&(origin, expires)) = self.location_cache.get(path) {
            if now < expires {
                self.cache_hits += 1;
                return Some(origin);
            }
            self.location_cache.remove(path);
        }
        self.broadcasts += 1;
        for o in origins.iter_mut() {
            if o.locate(path) {
                self.location_cache
                    .insert(path.to_string(), (o.id, now + self.cache_ttl));
                return Some(o.id);
            }
        }
        None
    }

    /// Drop a cached location (e.g. after an origin deletion event).
    pub fn invalidate(&mut self, path: &str) {
        self.location_cache.remove(path);
    }

    pub fn cached_locations(&self) -> usize {
        self.location_cache.len()
    }
}

/// Outcome of a pool lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LocateOutcome {
    pub origin: OriginId,
    /// Which instance answered.
    pub instance: usize,
    /// Instances tried (1 unless failover happened).
    pub attempts: usize,
}

/// Round-robin HA pool of redirectors (the OSG runs two — §3).
#[derive(Debug)]
pub struct RedirectorPool {
    pub instances: Vec<Redirector>,
    rr: usize,
}

/// Error when every instance is down.
#[derive(Debug, PartialEq)]
pub struct AllRedirectorsDown(pub usize);

impl std::fmt::Display for AllRedirectorsDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "all {} redirector instances are down", self.0)
    }
}

impl std::error::Error for AllRedirectorsDown {}

impl RedirectorPool {
    pub fn new(count: usize) -> Self {
        assert!(count >= 1);
        RedirectorPool {
            instances: (0..count).map(Redirector::new).collect(),
            rr: 0,
        }
    }

    /// Round-robin locate with failover across unhealthy instances.
    /// Returns `Ok(None)` when the path exists nowhere (a healthy
    /// instance answered "not found").
    pub fn locate(
        &mut self,
        path: &str,
        origins: &mut [Origin],
        now: SimTime,
    ) -> Result<Option<LocateOutcome>, AllRedirectorsDown> {
        let n = self.instances.len();
        for attempt in 0..n {
            let idx = (self.rr + attempt) % n;
            if !self.instances[idx].healthy {
                continue;
            }
            self.rr = (idx + 1) % n; // next query starts after the responder
            let found = self.instances[idx].locate(path, origins, now);
            return Ok(found.map(|origin| LocateOutcome {
                origin,
                instance: idx,
                attempts: attempt + 1,
            }));
        }
        Err(AllRedirectorsDown(n))
    }

    /// Mark an instance down/up (failure injection).
    pub fn set_healthy(&mut self, instance: usize, healthy: bool) {
        self.instances[instance].healthy = healthy;
    }

    /// Instances currently answering (the availability report's view
    /// of the HA pair).
    pub fn healthy_count(&self) -> usize {
        self.instances.iter().filter(|r| r.healthy).count()
    }

    pub fn total_queries(&self) -> u64 {
        self.instances.iter().map(|r| r.queries).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::origin::FileMeta;

    fn origins() -> Vec<Origin> {
        let mut o1 = Origin::new(OriginId(0), "o-ligo", "/ospool/ligo");
        o1.put_file("/ospool/ligo/f1", FileMeta { size: 10, mtime: 1, perm: 0o644 })
            .unwrap();
        let mut o2 = Origin::new(OriginId(1), "o-des", "/ospool/des");
        o2.put_file("/ospool/des/d1", FileMeta { size: 20, mtime: 1, perm: 0o644 })
            .unwrap();
        vec![o1, o2]
    }

    #[test]
    fn locates_correct_origin() {
        let mut os = origins();
        let mut r = Redirector::new(0);
        assert_eq!(
            r.locate("/ospool/des/d1", &mut os, SimTime::ZERO),
            Some(OriginId(1))
        );
        assert_eq!(r.locate("/nope", &mut os, SimTime::ZERO), None);
    }

    #[test]
    fn location_cache_avoids_rebroadcast() {
        let mut os = origins();
        let mut r = Redirector::new(0);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        let broadcasts_before = r.broadcasts;
        let queries_to_origins = os[0].locate_queries + os[1].locate_queries;
        r.locate("/ospool/ligo/f1", &mut os, SimTime::from_secs_f64(1.0));
        assert_eq!(r.broadcasts, broadcasts_before, "cache hit, no broadcast");
        assert_eq!(os[0].locate_queries + os[1].locate_queries, queries_to_origins);
        assert_eq!(r.cache_hits, 1);
    }

    #[test]
    fn location_cache_expires() {
        let mut os = origins();
        let mut r = Redirector::new(0);
        r.cache_ttl = Duration::from_secs(60);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::from_secs_f64(120.0));
        assert_eq!(r.broadcasts, 2, "expired entry re-broadcasts");
    }

    #[test]
    fn pool_round_robins() {
        let mut os = origins();
        let mut pool = RedirectorPool::new(2);
        let a = pool
            .locate("/ospool/ligo/f1", &mut os, SimTime::ZERO)
            .unwrap()
            .unwrap();
        let b = pool
            .locate("/ospool/des/d1", &mut os, SimTime::ZERO)
            .unwrap()
            .unwrap();
        assert_ne!(a.instance, b.instance, "round robin alternates");
    }

    #[test]
    fn pool_fails_over() {
        let mut os = origins();
        let mut pool = RedirectorPool::new(2);
        pool.set_healthy(0, false);
        for _ in 0..3 {
            let out = pool
                .locate("/ospool/ligo/f1", &mut os, SimTime::ZERO)
                .unwrap()
                .unwrap();
            assert_eq!(out.instance, 1);
        }
    }

    #[test]
    fn pool_all_down_errors() {
        let mut os = origins();
        let mut pool = RedirectorPool::new(2);
        pool.set_healthy(0, false);
        pool.set_healthy(1, false);
        assert_eq!(
            pool.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO),
            Err(AllRedirectorsDown(2))
        );
        // Recovery restores service.
        pool.set_healthy(1, true);
        assert!(pool
            .locate("/ospool/ligo/f1", &mut os, SimTime::ZERO)
            .unwrap()
            .is_some());
    }

    #[test]
    fn invalidate_forces_rebroadcast() {
        let mut os = origins();
        let mut r = Redirector::new(0);
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        r.invalidate("/ospool/ligo/f1");
        r.locate("/ospool/ligo/f1", &mut os, SimTime::ZERO);
        assert_eq!(r.broadcasts, 2);
        assert_eq!(r.cached_locations(), 1);
    }
}
