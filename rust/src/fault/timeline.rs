//! Builder for deterministic fault schedules.
//!
//! A [`FaultTimeline`] is just an ordered list of [`FaultEvent`]s with
//! convenience constructors for the common fault shapes (an outage is a
//! down/up pair, a brownout a degrade/restore pair). Inject one into a
//! federation with [`crate::federation::FedSim::inject_faults`]; every
//! engine driving that federation (serial `download`, campaigns, the
//! §4.1 scenario) then applies the events at their scheduled instants.

use crate::netsim::LinkId;
use crate::util::SimTime;
use super::{FaultEvent, FaultKind};

/// An ordered set of scheduled faults.
#[derive(Debug, Clone, Default)]
pub struct FaultTimeline {
    events: Vec<FaultEvent>,
}

impl FaultTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule one fault event.
    pub fn push(&mut self, at: SimTime, kind: FaultKind) -> &mut Self {
        self.events.push(FaultEvent { at, kind });
        self
    }

    /// A cache outage: down at `down`, back (warm) at `up`.
    pub fn cache_outage(&mut self, site: usize, down: SimTime, up: SimTime) -> &mut Self {
        assert!(down < up, "outage must end after it starts");
        self.push(down, FaultKind::CacheDown { site });
        self.push(up, FaultKind::CacheUp { site })
    }

    /// A link outage: severed at `cut`, healed at `restore`.
    pub fn link_outage(&mut self, link: LinkId, cut: SimTime, restore: SimTime) -> &mut Self {
        assert!(cut < restore, "outage must end after it starts");
        self.push(cut, FaultKind::LinkCut { link });
        self.push(restore, FaultKind::LinkRestored { link })
    }

    /// An origin brownout: DTN capacity scaled by `factor` in (0, 1]
    /// from `from` to `to`.
    pub fn origin_brownout(
        &mut self,
        origin: usize,
        factor: f64,
        from: SimTime,
        to: SimTime,
    ) -> &mut Self {
        assert!(
            factor > 0.0 && factor <= 1.0,
            "brownout factor must be in (0, 1], got {factor}"
        );
        assert!(from < to, "brownout must end after it starts");
        self.push(from, FaultKind::OriginDegraded { origin, factor });
        self.push(to, FaultKind::OriginRestored { origin })
    }

    /// A redirector-instance outage (the HA pair degrades to one).
    pub fn redirector_outage(
        &mut self,
        instance: usize,
        down: SimTime,
        up: SimTime,
    ) -> &mut Self {
        assert!(down < up, "outage must end after it starts");
        self.push(down, FaultKind::RedirectorDown { instance });
        self.push(up, FaultKind::RedirectorUp { instance })
    }

    /// The scheduled events, in insertion order (the federation sorts
    /// by time on injection; insertion order breaks ties).
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn builders_emit_paired_events() {
        let mut tl = FaultTimeline::new();
        tl.cache_outage(4, t(10.0), t(20.0))
            .origin_brownout(0, 0.25, t(5.0), t(15.0));
        assert_eq!(tl.len(), 4);
        assert_eq!(
            tl.events()[0],
            FaultEvent {
                at: t(10.0),
                kind: FaultKind::CacheDown { site: 4 }
            }
        );
        assert_eq!(
            tl.events()[3],
            FaultEvent {
                at: t(15.0),
                kind: FaultKind::OriginRestored { origin: 0 }
            }
        );
    }

    #[test]
    #[should_panic(expected = "outage must end after it starts")]
    fn inverted_outage_panics() {
        FaultTimeline::new().cache_outage(0, t(5.0), t(5.0));
    }

    #[test]
    #[should_panic(expected = "brownout factor")]
    fn zero_factor_panics() {
        FaultTimeline::new().origin_brownout(0, 0.0, t(1.0), t(2.0));
    }
}
