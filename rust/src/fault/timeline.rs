//! Builder for deterministic fault schedules.
//!
//! A [`FaultTimeline`] is just an ordered list of [`FaultEvent`]s with
//! convenience constructors for the common fault shapes (an outage is a
//! down/up pair, a brownout a degrade/restore pair). Inject one into a
//! federation with [`crate::federation::FedSim::inject_faults`]; every
//! engine driving that federation (serial `download`, campaigns, the
//! §4.1 scenario) then applies the events at their scheduled instants.

use crate::netsim::LinkId;
use crate::util::SimTime;
use super::{FaultEvent, FaultKind};
use std::collections::BTreeMap;
use std::collections::BTreeSet;
use std::fmt;

/// An ordered set of scheduled faults.
#[derive(Debug, Clone, Default)]
pub struct FaultTimeline {
    events: Vec<FaultEvent>,
}

/// The federation dimensions a timeline is validated against — what
/// exists to fail. Built by [`crate::federation::FedSim::fault_dims`].
#[derive(Debug, Clone, Default)]
pub struct FaultDims {
    /// Site indices that host a cache (cache faults must hit one).
    pub cache_sites: BTreeSet<usize>,
    /// Number of origins.
    pub origins: usize,
    /// Number of network links.
    pub links: usize,
    /// Number of redirector instances.
    pub redirector_instances: usize,
}

/// Why a fault timeline was rejected at injection time. Every variant
/// is a schedule that would otherwise panic (or silently misbehave)
/// deep inside the engine mid-run.
#[derive(Debug, Clone, PartialEq)]
pub enum TimelineError {
    /// A cache fault names a site with no cache (or out of range).
    UnknownCacheSite { event: String, site: usize },
    /// An origin fault's index is out of range.
    OriginOutOfRange { event: String, origin: usize, origins: usize },
    /// A link fault's index is out of range.
    LinkOutOfRange { event: String, link: u32, links: usize },
    /// A redirector fault's instance is out of range.
    InstanceOutOfRange { event: String, instance: usize, instances: usize },
    /// A recovery event (`*Up` / `*Restored`) with no matching open
    /// failure at its instant.
    UnmatchedRecovery { event: String, at: SimTime },
    /// A recovery scheduled at or before the failure it closes.
    NonMonotone { event: String, opened_at: SimTime, at: SimTime },
    /// A degrade factor outside (0, 1].
    BadFactor { event: String, factor: f64 },
    /// A `DataCorrupt` with an empty path.
    EmptyPath { at: SimTime },
}

impl fmt::Display for TimelineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TimelineError::UnknownCacheSite { event, site } => {
                write!(f, "{event} names site {site}, which hosts no cache")
            }
            TimelineError::OriginOutOfRange { event, origin, origins } => {
                write!(f, "{event} names origin {origin}, but only {origins} exist")
            }
            TimelineError::LinkOutOfRange { event, link, links } => {
                write!(f, "{event} names link {link}, but only {links} exist")
            }
            TimelineError::InstanceOutOfRange { event, instance, instances } => write!(
                f,
                "{event} names redirector {instance}, but only {instances} exist"
            ),
            TimelineError::UnmatchedRecovery { event, at } => {
                write!(f, "{event} at {at} has no matching open failure")
            }
            TimelineError::NonMonotone { event, opened_at, at } => write!(
                f,
                "{event} at {at} does not strictly follow the failure it closes (opened at {opened_at})"
            ),
            TimelineError::BadFactor { event, factor } => {
                write!(f, "{event} factor must be in (0, 1], got {factor}")
            }
            TimelineError::EmptyPath { at } => {
                write!(f, "DataCorrupt at {at} has an empty path")
            }
        }
    }
}

impl std::error::Error for TimelineError {}

impl FaultTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule one fault event.
    pub fn push(&mut self, at: SimTime, kind: FaultKind) -> &mut Self {
        self.events.push(FaultEvent { at, kind });
        self
    }

    /// A cache outage: down at `down`, back (warm) at `up`.
    pub fn cache_outage(&mut self, site: usize, down: SimTime, up: SimTime) -> &mut Self {
        assert!(down < up, "outage must end after it starts");
        self.push(down, FaultKind::CacheDown { site });
        self.push(up, FaultKind::CacheUp { site })
    }

    /// A link outage: severed at `cut`, healed at `restore`.
    pub fn link_outage(&mut self, link: LinkId, cut: SimTime, restore: SimTime) -> &mut Self {
        assert!(cut < restore, "outage must end after it starts");
        self.push(cut, FaultKind::LinkCut { link });
        self.push(restore, FaultKind::LinkRestored { link })
    }

    /// An origin brownout: DTN capacity scaled by `factor` in (0, 1]
    /// from `from` to `to`.
    pub fn origin_brownout(
        &mut self,
        origin: usize,
        factor: f64,
        from: SimTime,
        to: SimTime,
    ) -> &mut Self {
        assert!(
            factor > 0.0 && factor <= 1.0,
            "brownout factor must be in (0, 1], got {factor}"
        );
        assert!(from < to, "brownout must end after it starts");
        self.push(from, FaultKind::OriginDegraded { origin, factor });
        self.push(to, FaultKind::OriginRestored { origin })
    }

    /// A cache slowdown (gray failure): the cache's serving links run
    /// at `factor` of capacity from `from` to `to`.
    pub fn cache_slowdown(
        &mut self,
        site: usize,
        factor: f64,
        from: SimTime,
        to: SimTime,
    ) -> &mut Self {
        assert!(
            factor > 0.0 && factor <= 1.0,
            "slowdown factor must be in (0, 1], got {factor}"
        );
        assert!(from < to, "slowdown must end after it starts");
        self.push(from, FaultKind::CacheSlow { site, factor });
        self.push(to, FaultKind::CacheRestored { site })
    }

    /// Silent corruption of one resident file at a cache. No paired
    /// recovery: the poison clears when a client detects it and the
    /// refetched bytes commit.
    pub fn data_corruption(&mut self, site: usize, path: impl Into<String>, at: SimTime) -> &mut Self {
        self.push(
            at,
            FaultKind::DataCorrupt {
                site,
                path: path.into(),
            },
        )
    }

    /// A redirector-instance outage (the HA pair degrades to one).
    pub fn redirector_outage(
        &mut self,
        instance: usize,
        down: SimTime,
        up: SimTime,
    ) -> &mut Self {
        assert!(down < up, "outage must end after it starts");
        self.push(down, FaultKind::RedirectorDown { instance });
        self.push(up, FaultKind::RedirectorUp { instance })
    }

    /// The scheduled events, in insertion order (the federation sorts
    /// by time on injection; insertion order breaks ties).
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Check the schedule against the federation's dimensions: every
    /// index exists, every recovery closes an open failure, and every
    /// recovery strictly follows the failure it closes. Runs at
    /// injection time ([`crate::federation::FedSim::inject_faults`]),
    /// so a bad schedule is a typed error up front instead of an
    /// engine panic hours into a run.
    ///
    /// Events are walked in applied order (stable sort by instant,
    /// insertion order breaking ties) — the same order the engine
    /// fires them. A failure with no recovery is valid (the component
    /// stays dark); duplicate failures are idempotent, like
    /// [`super::FaultState`].
    pub fn validate(&self, dims: &FaultDims) -> Result<(), TimelineError> {
        let mut order: Vec<usize> = (0..self.events.len()).collect();
        order.sort_by_key(|&i| self.events[i].at);

        let cache_site = |event: &str, site: usize| -> Result<(), TimelineError> {
            if dims.cache_sites.contains(&site) {
                Ok(())
            } else {
                Err(TimelineError::UnknownCacheSite {
                    event: event.into(),
                    site,
                })
            }
        };
        let factor_ok = |event: &str, factor: f64| -> Result<(), TimelineError> {
            if factor > 0.0 && factor <= 1.0 && factor.is_finite() {
                Ok(())
            } else {
                Err(TimelineError::BadFactor {
                    event: event.into(),
                    factor,
                })
            }
        };
        // Open failures by component, keyed on the instant they began.
        let mut down: BTreeMap<usize, SimTime> = BTreeMap::new();
        let mut slow: BTreeMap<usize, SimTime> = BTreeMap::new();
        let mut cut: BTreeMap<u32, SimTime> = BTreeMap::new();
        let mut degraded: BTreeMap<usize, SimTime> = BTreeMap::new();
        let mut redirector: BTreeMap<usize, SimTime> = BTreeMap::new();
        let close = |opened: Option<SimTime>, event: &str, at: SimTime| -> Result<(), TimelineError> {
            match opened {
                None => Err(TimelineError::UnmatchedRecovery {
                    event: event.into(),
                    at,
                }),
                Some(opened_at) if opened_at >= at => Err(TimelineError::NonMonotone {
                    event: event.into(),
                    opened_at,
                    at,
                }),
                Some(_) => Ok(()),
            }
        };

        for &i in &order {
            let ev = &self.events[i];
            let at = ev.at;
            match &ev.kind {
                FaultKind::CacheDown { site } => {
                    cache_site("CacheDown", *site)?;
                    down.entry(*site).or_insert(at);
                }
                FaultKind::CacheUp { site } => {
                    cache_site("CacheUp", *site)?;
                    close(down.get(site).copied(), "CacheUp", at)?;
                    down.remove(site);
                }
                FaultKind::CacheSlow { site, factor } => {
                    cache_site("CacheSlow", *site)?;
                    factor_ok("CacheSlow", *factor)?;
                    slow.entry(*site).or_insert(at);
                }
                FaultKind::CacheRestored { site } => {
                    cache_site("CacheRestored", *site)?;
                    close(slow.get(site).copied(), "CacheRestored", at)?;
                    slow.remove(site);
                }
                FaultKind::DataCorrupt { site, path } => {
                    cache_site("DataCorrupt", *site)?;
                    if path.is_empty() {
                        return Err(TimelineError::EmptyPath { at });
                    }
                }
                FaultKind::LinkCut { link } => {
                    if link.0 as usize >= dims.links {
                        return Err(TimelineError::LinkOutOfRange {
                            event: "LinkCut".into(),
                            link: link.0,
                            links: dims.links,
                        });
                    }
                    cut.entry(link.0).or_insert(at);
                }
                FaultKind::LinkRestored { link } => {
                    if link.0 as usize >= dims.links {
                        return Err(TimelineError::LinkOutOfRange {
                            event: "LinkRestored".into(),
                            link: link.0,
                            links: dims.links,
                        });
                    }
                    close(cut.get(&link.0).copied(), "LinkRestored", at)?;
                    cut.remove(&link.0);
                }
                FaultKind::OriginDegraded { origin, factor } => {
                    if *origin >= dims.origins {
                        return Err(TimelineError::OriginOutOfRange {
                            event: "OriginDegraded".into(),
                            origin: *origin,
                            origins: dims.origins,
                        });
                    }
                    factor_ok("OriginDegraded", *factor)?;
                    degraded.entry(*origin).or_insert(at);
                }
                FaultKind::OriginRestored { origin } => {
                    if *origin >= dims.origins {
                        return Err(TimelineError::OriginOutOfRange {
                            event: "OriginRestored".into(),
                            origin: *origin,
                            origins: dims.origins,
                        });
                    }
                    close(degraded.get(origin).copied(), "OriginRestored", at)?;
                    degraded.remove(origin);
                }
                FaultKind::RedirectorDown { instance } => {
                    if *instance >= dims.redirector_instances {
                        return Err(TimelineError::InstanceOutOfRange {
                            event: "RedirectorDown".into(),
                            instance: *instance,
                            instances: dims.redirector_instances,
                        });
                    }
                    redirector.entry(*instance).or_insert(at);
                }
                FaultKind::RedirectorUp { instance } => {
                    if *instance >= dims.redirector_instances {
                        return Err(TimelineError::InstanceOutOfRange {
                            event: "RedirectorUp".into(),
                            instance: *instance,
                            instances: dims.redirector_instances,
                        });
                    }
                    close(redirector.get(instance).copied(), "RedirectorUp", at)?;
                    redirector.remove(instance);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn builders_emit_paired_events() {
        let mut tl = FaultTimeline::new();
        tl.cache_outage(4, t(10.0), t(20.0))
            .origin_brownout(0, 0.25, t(5.0), t(15.0));
        assert_eq!(tl.len(), 4);
        assert_eq!(
            tl.events()[0],
            FaultEvent {
                at: t(10.0),
                kind: FaultKind::CacheDown { site: 4 }
            }
        );
        assert_eq!(
            tl.events()[3],
            FaultEvent {
                at: t(15.0),
                kind: FaultKind::OriginRestored { origin: 0 }
            }
        );
    }

    #[test]
    #[should_panic(expected = "outage must end after it starts")]
    fn inverted_outage_panics() {
        FaultTimeline::new().cache_outage(0, t(5.0), t(5.0));
    }

    #[test]
    #[should_panic(expected = "brownout factor")]
    fn zero_factor_panics() {
        FaultTimeline::new().origin_brownout(0, 0.0, t(1.0), t(2.0));
    }

    fn dims() -> FaultDims {
        FaultDims {
            cache_sites: [0, 3].into_iter().collect(),
            origins: 2,
            links: 8,
            redirector_instances: 2,
        }
    }

    #[test]
    fn valid_timeline_passes_validation() {
        let mut tl = FaultTimeline::new();
        tl.cache_outage(3, t(10.0), t(20.0))
            .cache_slowdown(0, 0.05, t(5.0), t(30.0))
            .origin_brownout(1, 0.25, t(1.0), t(2.0))
            .link_outage(LinkId(7), t(3.0), t(4.0))
            .redirector_outage(1, t(0.5), t(9.0))
            .data_corruption(0, "/ospool/x", t(6.0));
        tl.validate(&dims()).unwrap();
        // A failure with no recovery is a valid schedule too.
        let mut dark = FaultTimeline::new();
        dark.push(t(1.0), FaultKind::CacheDown { site: 0 });
        dark.validate(&dims()).unwrap();
    }

    #[test]
    fn rejects_recovery_without_open_failure() {
        let mut tl = FaultTimeline::new();
        tl.push(t(5.0), FaultKind::CacheUp { site: 0 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::UnmatchedRecovery { .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(5.0), FaultKind::CacheRestored { site: 0 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::UnmatchedRecovery { .. }
        ));
        // A slowdown does not satisfy a CacheUp (separate ledgers).
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::CacheSlow { site: 0, factor: 0.5 });
        tl.push(t(2.0), FaultKind::CacheUp { site: 0 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::UnmatchedRecovery { .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(5.0), FaultKind::LinkRestored { link: LinkId(1) });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::UnmatchedRecovery { .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(5.0), FaultKind::OriginRestored { origin: 0 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::UnmatchedRecovery { .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(5.0), FaultKind::RedirectorUp { instance: 0 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::UnmatchedRecovery { .. }
        ));
    }

    #[test]
    fn rejects_out_of_range_indices() {
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::CacheDown { site: 1 }); // site 1 has no cache
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::UnknownCacheSite { site: 1, .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::CacheSlow { site: 99, factor: 0.5 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::UnknownCacheSite { site: 99, .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::OriginDegraded { origin: 2, factor: 0.5 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::OriginOutOfRange { origin: 2, .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::LinkCut { link: LinkId(8) });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::LinkOutOfRange { link: 8, .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::RedirectorDown { instance: 2 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::InstanceOutOfRange { instance: 2, .. }
        ));
    }

    #[test]
    fn rejects_non_monotone_pairs() {
        // Same-instant down/up pushed out of builder reach: the
        // recovery does not strictly follow the failure.
        let mut tl = FaultTimeline::new();
        tl.push(t(5.0), FaultKind::CacheDown { site: 0 });
        tl.push(t(5.0), FaultKind::CacheUp { site: 0 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::NonMonotone { .. }
        ));
    }

    #[test]
    fn rejects_bad_factors_and_empty_paths() {
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::CacheSlow { site: 0, factor: 0.0 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::BadFactor { .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::OriginDegraded { origin: 0, factor: 1.5 });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::BadFactor { .. }
        ));
        let mut tl = FaultTimeline::new();
        tl.push(t(1.0), FaultKind::DataCorrupt { site: 0, path: String::new() });
        assert!(matches!(
            tl.validate(&dims()).unwrap_err(),
            TimelineError::EmptyPath { .. }
        ));
    }

    #[test]
    fn validation_walks_in_time_order_not_insertion_order() {
        // Recovery inserted first but scheduled after the failure is
        // fine — injection sorts by instant.
        let mut tl = FaultTimeline::new();
        tl.push(t(20.0), FaultKind::CacheUp { site: 0 });
        tl.push(t(10.0), FaultKind::CacheDown { site: 0 });
        tl.validate(&dims()).unwrap();
    }
}
