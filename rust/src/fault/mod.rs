//! Fault timeline: scheduled mid-run failures of federation components.
//!
//! The paper's core operational claim (§1, §3) is that opportunistic
//! resources can vanish at any moment — "the resource provider can
//! reclaim space in the cache without worry of causing workflow
//! failures" — and in production (the OSDF follow-up work) cache
//! hosts, links, and origins go down routinely while thousands of
//! transfers are in flight. This module is the deterministic chaos
//! layer that reproduces those outages:
//!
//! * [`FaultKind`] / [`FaultEvent`] — what fails, and when.
//! * [`FaultTimeline`] — a builder for scheduled fault sequences,
//!   injected into a federation with
//!   [`crate::federation::FedSim::inject_faults`].
//! * [`FaultState`] — the live health view (which caches are down,
//!   per-cache accumulated downtime) the engine and GeoIP consult.
//!
//! The engine ([`crate::federation::driver::SessionEngine`]) treats the
//! fault schedule as a third event source next to its timer queue and
//! the network's completions: network completions at or before a fault
//! instant drain first (a transfer that finished, finished), then the
//! fault applies, then same-instant timers observe the post-fault
//! world. Sessions whose cache dies mid-transfer abort their in-flight
//! chunks, wake any joined waiters, and re-enter `GeoResolve` with the
//! dead cache excluded; after [`MAX_FAILOVER_RETRIES`] failed attempts
//! they stream directly from the origin. See `ARCHITECTURE.md` ("Fault
//! layer") for the full event flow.

pub mod timeline;

pub use timeline::{FaultDims, FaultTimeline, TimelineError};

use crate::netsim::LinkId;
use crate::util::{Duration, SimTime};
use std::collections::BTreeMap;

/// Mid-transfer failures re-resolve (GeoIP + reconnect) and retry this
/// many times before the session gives up on caches entirely and
/// streams from the origin (stashcp's last-resort behaviour).
pub const MAX_FAILOVER_RETRIES: u32 = 3;

/// Poll interval for a direct-to-origin session whose own path is cut:
/// there is nothing left to fail over to, so it waits for the link to
/// heal and tries again.
pub const DIRECT_RETRY_BACKOFF: Duration = Duration::from_secs(2);

/// One kind of component failure (or recovery).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// The cache at `cfg.sites[site]` becomes unreachable. In-flight
    /// transfers it serves abort; its disk contents survive.
    CacheDown { site: usize },
    /// The cache comes back (warm: resident chunks survived).
    CacheUp { site: usize },
    /// The origin's DTN link capacity is scaled by `factor` in (0, 1]
    /// (brownout: many users, a failed disk array, a drained node).
    OriginDegraded { origin: usize, factor: f64 },
    /// The origin's DTN link returns to full capacity.
    OriginRestored { origin: usize },
    /// A network link is severed: every flow crossing it dies and new
    /// flows cannot use it until restored.
    LinkCut { link: LinkId },
    /// The link comes back up.
    LinkRestored { link: LinkId },
    /// A redirector instance stops answering (HA pair degrades).
    RedirectorDown { instance: usize },
    /// The redirector instance recovers.
    RedirectorUp { instance: usize },
    /// Gray failure: the cache still answers, but its serving links
    /// (worker LAN + WAN legs) degrade to `factor` of capacity — a
    /// sick disk array, an overloaded host, a half-dead NIC. Sessions
    /// keep transferring; only a transfer deadline (or the circuit
    /// breaker) gets them off the slow cache.
    CacheSlow { site: usize, factor: f64 },
    /// The slow cache's serving links return to full capacity.
    CacheRestored { site: usize },
    /// A resident copy of `path` at the cache is silently corrupted.
    /// Clients detect the damage at transfer end via the content
    /// digest ([`crate::origin::content`]) and must exclude-and-refetch;
    /// a fresh origin fetch replaces the poisoned bytes.
    DataCorrupt { site: usize, path: String },
}

/// A scheduled fault: `kind` applies at virtual time `at`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub at: SimTime,
    pub kind: FaultKind,
}

/// Live component-health view, updated as fault events apply and read
/// by the engine (connection checks) and GeoIP (down caches are never
/// ranked). Also the per-cache downtime ledger for the availability
/// section of the report.
#[derive(Debug, Default, Clone)]
pub struct FaultState {
    /// cache site → instant the current outage began.
    down_since: BTreeMap<usize, SimTime>,
    /// cache site → accumulated downtime over *closed* outages.
    downtime: BTreeMap<usize, Duration>,
    /// cache site → number of outages started.
    outages: BTreeMap<usize, u32>,
}

impl FaultState {
    /// Is this cache site currently unreachable?
    pub fn is_cache_down(&self, site: usize) -> bool {
        self.down_since.contains_key(&site)
    }

    /// Mark a cache down at `now` (idempotent: a duplicate down event
    /// does not restart the outage clock).
    pub(crate) fn cache_down(&mut self, site: usize, now: SimTime) {
        if let std::collections::btree_map::Entry::Vacant(e) = self.down_since.entry(site) {
            e.insert(now);
            *self.outages.entry(site).or_insert(0) += 1;
        }
    }

    /// Mark a cache back up at `now`, closing the open outage
    /// (idempotent: up without a preceding down is a no-op).
    pub(crate) fn cache_up(&mut self, site: usize, now: SimTime) {
        if let Some(since) = self.down_since.remove(&site) {
            *self.downtime.entry(site).or_insert(Duration::ZERO) += now.saturating_sub(since);
        }
    }

    /// Outages started at this cache so far.
    pub fn outages_of(&self, site: usize) -> u32 {
        self.outages.get(&site).copied().unwrap_or(0)
    }

    /// Accumulated downtime of a cache, including a still-open outage
    /// measured up to `now`.
    pub fn downtime_of(&self, site: usize, now: SimTime) -> Duration {
        let mut d = self.downtime.get(&site).copied().unwrap_or(Duration::ZERO);
        if let Some(&since) = self.down_since.get(&site) {
            d += now.saturating_sub(since);
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn downtime_accumulates_across_outages() {
        let mut f = FaultState::default();
        f.cache_down(3, t(10.0));
        f.cache_up(3, t(25.0));
        f.cache_down(3, t(100.0));
        f.cache_up(3, t(105.0));
        assert_eq!(f.downtime_of(3, t(200.0)), Duration::from_secs(20));
        assert_eq!(f.outages_of(3), 2);
        assert!(!f.is_cache_down(3));
    }

    #[test]
    fn open_outage_counts_up_to_now() {
        let mut f = FaultState::default();
        f.cache_down(0, t(5.0));
        assert!(f.is_cache_down(0));
        assert_eq!(f.downtime_of(0, t(12.0)), Duration::from_secs(7));
        // Other sites are unaffected.
        assert!(!f.is_cache_down(1));
        assert_eq!(f.downtime_of(1, t(12.0)), Duration::ZERO);
    }

    #[test]
    fn duplicate_events_are_idempotent() {
        let mut f = FaultState::default();
        f.cache_down(2, t(1.0));
        f.cache_down(2, t(3.0)); // must not restart the clock
        f.cache_up(2, t(11.0));
        f.cache_up(2, t(12.0)); // must not double-count
        assert_eq!(f.downtime_of(2, t(20.0)), Duration::from_secs(10));
        assert_eq!(f.outages_of(2), 1);
    }
}
