//! Paper-artifact generators: one function per table/figure.
//!
//! Each runs the corresponding experiment on the default federation
//! and renders the measured result next to the paper's published
//! numbers, so `cargo bench` output reads as a reproduction report.
//! The *shape* assertions (who wins, where) live in the bench targets
//! and integration tests; EXPERIMENTS.md records the comparison.

use super::{bar_chart, grouped_bars, Table};
use crate::config::defaults::{self, paper_federation, COMPUTE_SITES};
use crate::monitoring::availability::AvailabilityReport;
use crate::sim::scenario::{self, ScenarioConfig, ScenarioResults};
use crate::sim::usage::{self, UsageConfig};
use crate::util::ByteSize;

/// Paper's Table 1 (for the side-by-side column).
pub const PAPER_TABLE1: [(&str, &str); 9] = [
    ("gwosc", "1.079PB"),
    ("des", "709.051TB"),
    ("minerva", "514.794TB"),
    ("ligo", "228.324TB"),
    ("osg-testing", "184.773TB"),
    ("nova", "24.317TB"),
    ("lsst", "18.966TB"),
    ("bioinformatics", "17.566TB"),
    ("dune", "11.677TB"),
];

/// Paper's Table 2.
pub const PAPER_TABLE2: [(f64, &str); 7] = [
    (1.0, "5.797KB"),
    (5.0, "22.801MB"),
    (25.0, "170.131MB"),
    (50.0, "467.852MB"),
    (75.0, "493.337MB"),
    (95.0, "2.335GB"),
    (99.0, "2.335GB"),
];

/// Paper's Table 3 (%Δ http→stash; negative ⇒ StashCache faster).
pub const PAPER_TABLE3: [(&str, f64, f64); 5] = [
    ("bellarmine", -68.5, -10.0),
    ("syracuse", 0.9, -26.3),
    ("colorado", 506.5, 245.9),
    ("nebraska", -12.1, -2.1),
    ("chicago", 30.6, -7.7),
];

/// Default six-month-equivalent usage run, scaled for minutes-level
/// wall clock (the monitoring maths is volume-independent).
pub fn default_usage_cfg() -> UsageConfig {
    UsageConfig {
        days: 3.0,
        jobs_per_hour: Some(120.0),
        background_flows: 2,
        weekly_intensity: Vec::new(),
        wan_bucket_secs: 1_800.0,
    }
}

/// Table 1: top users by usage, measured vs paper share.
pub fn table1(ucfg: &UsageConfig) -> (Table, Vec<(String, ByteSize)>) {
    let mut out = usage::run(paper_federation(), ucfg);
    let measured = out.aggregator().table1();
    let total: f64 = measured.iter().map(|(_, b)| b.as_f64()).sum();
    let paper_total: f64 = defaults::paper_workload()
        .experiments
        .iter()
        .map(|e| e.share)
        .sum();
    let mut t = Table::new(
        "Table 1: StashCache usage by experiment (measured via monitoring pipeline)",
        &["Experiment", "Measured", "Share", "Paper share", "Paper usage"],
    );
    for (name, bytes) in &measured {
        let paper_share = defaults::paper_workload()
            .experiments
            .iter()
            .find(|e| e.name == *name)
            .map(|e| e.share / paper_total * 100.0);
        let paper_usage = PAPER_TABLE1
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, u)| *u)
            .unwrap_or("-");
        t.row(vec![
            name.clone(),
            bytes.to_string(),
            format!("{:.1}%", bytes.as_f64() / total * 100.0),
            paper_share.map_or("-".into(), |s| format!("{s:.1}%")),
            paper_usage.to_string(),
        ]);
    }
    (t, measured)
}

/// Table 2: file-size percentiles from the monitoring histogram.
pub fn table2(ucfg: &UsageConfig) -> (Table, Vec<(f64, ByteSize)>) {
    let mut out = usage::run(paper_federation(), ucfg);
    let ps: Vec<f64> = PAPER_TABLE2.iter().map(|(p, _)| *p).collect();
    let est = out.aggregator().table2(&ps);
    let exact = out.aggregator().table2_exact(&ps);
    let mut t = Table::new(
        "Table 2: file-size percentiles (histogram kernel vs exact vs paper)",
        &["Percentile", "Histogram", "Exact", "Paper"],
    );
    for (((p, hist), (_, ex)), (_, paper)) in est.iter().zip(&exact).zip(&PAPER_TABLE2) {
        t.row(vec![
            format!("{p:.0}"),
            hist.to_string(),
            ex.to_string(),
            paper.to_string(),
        ]);
    }
    (t, est)
}

/// Run the §4.1 scenario once for figures 6-8 and Table 3.
pub fn run_scenario() -> ScenarioResults {
    scenario::run(paper_federation(), &ScenarioConfig::default())
}

/// Table 3: percent difference per site for the 2.3 GB and 10 GB
/// files, next to the paper's cells.
pub fn table3(results: &ScenarioResults) -> Table {
    let mut t = Table::new(
        "Table 3: HTTP proxy vs StashCache, %Δ download time (negative ⇒ StashCache faster)",
        &["Site", "2.3GB", "10GB", "paper 2.3GB", "paper 10GB"],
    );
    for (site, p23, p10) in PAPER_TABLE3 {
        let m23 = results.pct_difference(site, "p95");
        let m10 = results.pct_difference(site, "f10g");
        t.row(vec![
            site.to_string(),
            m23.map_or("-".into(), |v| format!("{v:+.1}%")),
            m10.map_or("-".into(), |v| format!("{v:+.1}%")),
            format!("{p23:+.1}%"),
            format!("{p10:+.1}%"),
        ]);
    }
    t
}

/// Frontier report of a parameter sweep: every pair of grid cells
/// that differ only in client method, HTTP proxy vs StashCache side
/// by side (the Table 3 comparison generalised over cache capacity,
/// concurrency, size mix, and fault profile). Negative %Δ ⇒ StashCache
/// faster at the p95 download time, mirroring Table 3's convention.
pub fn frontier_table(results: &crate::experiment::SweepResults) -> Table {
    use crate::experiment::grid::method_name;
    use crate::federation::DownloadMethod;
    let mut t = Table::new(
        format!(
            "Frontier {:?}: HTTP proxy vs StashCache per cell (negative %Δ p95 ⇒ StashCache faster)",
            results.grid.name
        ),
        &["Cell", "stash Mbps", "http Mbps", "stash p95 s", "http p95 s", "%Δ p95", "winner"],
    );
    for s in &results.cells {
        if s.cell.method != DownloadMethod::Stash {
            continue;
        }
        let Some(h) = results.cells.iter().find(|c| {
            c.cell.method == DownloadMethod::HttpProxy
                && c.cell.base_label() == s.cell.base_label()
        }) else {
            continue;
        };
        let pct = if h.p95_s.mean > 0.0 {
            (s.p95_s.mean - h.p95_s.mean) / h.p95_s.mean * 100.0
        } else {
            0.0
        };
        let winner = if pct < 0.0 {
            method_name(DownloadMethod::Stash)
        } else {
            method_name(DownloadMethod::HttpProxy)
        };
        t.row(vec![
            s.cell.base_label(),
            format!("{:.0}", s.aggregate_mbps.mean),
            format!("{:.0}", h.aggregate_mbps.mean),
            format!("{:.2}", s.p95_s.mean),
            format!("{:.2}", h.p95_s.mean),
            format!("{pct:+.1}%"),
            winner.to_string(),
        ]);
    }
    t
}

/// Resilience report of a parameter sweep: every pair of grid cells
/// that differ only in the circuit breaker, side by side. The twins
/// share a workload seed and a fault schedule, so the comparison
/// isolates the breaker. Under a gray failure (`faults=degraded`) the
/// breaker-on column wins on goodput: the first deadline expiries trip
/// the breaker and every later session skips the slow cache outright
/// instead of paying a deadline before failing over.
pub fn resilience_table(results: &crate::experiment::SweepResults) -> Table {
    let mut t = Table::new(
        format!(
            "Resilience {:?}: circuit breaker off vs on per cell \
             (identical workload + fault schedule)",
            results.grid.name
        ),
        &[
            "Cell", "off Mbps", "on Mbps", "off p99 s", "on p99 s",
            "off origin GB", "on origin GB", "off expiries", "on expiries",
            "%Δ goodput",
        ],
    );
    for s in &results.cells {
        if s.cell.breaker {
            continue;
        }
        let Some(on) = results.cells.iter().find(|c| {
            c.cell.breaker
                && c.cell.resilience_pair_label() == s.cell.resilience_pair_label()
        }) else {
            continue;
        };
        let pct = if s.aggregate_mbps.mean > 0.0 {
            (on.aggregate_mbps.mean - s.aggregate_mbps.mean) / s.aggregate_mbps.mean * 100.0
        } else {
            0.0
        };
        t.row(vec![
            s.cell.resilience_pair_label(),
            format!("{:.0}", s.aggregate_mbps.mean),
            format!("{:.0}", on.aggregate_mbps.mean),
            format!("{:.2}", s.p99_s.mean),
            format!("{:.2}", on.p99_s.mean),
            format!("{:.2}", s.origin_gb.mean),
            format!("{:.2}", on.origin_gb.mean),
            format!("{:.1}", s.deadline_expiries.mean),
            format!("{:.1}", on.deadline_expiries.mean),
            format!("{pct:+.1}%"),
        ]);
    }
    t
}

/// Redirection-policy comparison of a parameter sweep: for every
/// workload cell (same jobs, skew, sizes, faults — and the same
/// workload *realization*, since policy variants share trial seeds),
/// each cache-selection policy's hit ratio, origin bytes, and p95
/// transfer time side by side. This is where consistent hashing's
/// origin-traffic collapse shows up: one Zipf-hot file fetched once
/// federation-wide instead of once per site.
pub fn policy_table(results: &crate::experiment::SweepResults) -> Table {
    use crate::experiment::grid::method_name;
    let mut t = Table::new(
        format!(
            "Redirection policies {:?}: per-cell hit ratio / origin bytes / p95",
            results.grid.name
        ),
        &["Cell", "method", "policy", "hit%", "origin GB", "p95 s", "failovers"],
    );
    // Group policy variants of one workload cell together: walk the
    // distinct (workload, method) pairs in first-appearance order,
    // then the policies in grid order within each.
    let mut groups: Vec<(String, crate::federation::DownloadMethod)> = Vec::new();
    for c in &results.cells {
        let key = (c.cell.workload_label(), c.cell.method);
        if !groups.contains(&key) {
            groups.push(key);
        }
    }
    for (workload, method) in groups {
        for c in results.cells.iter().filter(|c| {
            c.cell.method == method && c.cell.workload_label() == workload
        }) {
            t.row(vec![
                workload.clone(),
                method_name(method).to_string(),
                c.cell.policy.name().to_string(),
                format!("{:.1}", 100.0 * c.hit_ratio.mean),
                format!("{:.2}", c.origin_gb.mean),
                format!("{:.2}", c.p95_s.mean),
                format!("{:.1}", c.failovers.mean),
            ]);
        }
    }
    t
}

/// The sweep's Table 3 cell next to the paper's published numbers
/// (same convention as [`table3`]).
pub fn sweep_table3(cell: &crate::experiment::Table3Cell) -> Table {
    let mut t = Table::new(
        "Table 3 cell: %Δ download time, HTTP proxy vs StashCache (§4.1 serial scenario)",
        &["Site", "2.3GB", "10GB", "paper 2.3GB", "paper 10GB"],
    );
    for row in &cell.rows {
        let paper = PAPER_TABLE3.iter().find(|(s, _, _)| *s == row.site);
        let fmt = |v: Option<f64>| v.map_or("-".into(), |x| format!("{x:+.1}%"));
        t.row(vec![
            row.site.clone(),
            fmt(row.pct_2_3gb),
            fmt(row.pct_10gb),
            paper.map_or("-".into(), |(_, p, _)| format!("{p:+.1}%")),
            paper.map_or("-".into(), |(_, _, p)| format!("{p:+.1}%")),
        ]);
    }
    t
}

/// Availability section: per-cache downtime and the fault-layer
/// counters from a chaos run (the operational follow-on to the
/// paper's §1 "reclaim space without causing workflow failures" claim:
/// every download in the window completed despite the faults below).
pub fn availability_table(report: &AvailabilityReport) -> Table {
    let mut t = Table::new(
        format!(
            "Availability over {}: {} faults, {} failovers, {} retries, \
             {} direct-to-origin, {} aborted mid-flight, {} downloads completed",
            report.window,
            report.faults_applied,
            report.failovers,
            report.retries,
            report.direct_fallbacks,
            ByteSize(report.aborted_bytes),
            report.downloads_completed,
        ),
        &["Cache", "Outages", "Downtime", "Availability"],
    );
    for c in &report.caches {
        t.row(vec![
            c.site.clone(),
            c.outages.to_string(),
            if c.downtime.as_micros() == 0 {
                "-".into()
            } else {
                c.downtime.to_string()
            },
            format!("{:.2}%", 100.0 * c.availability(report.window)),
        ]);
    }
    t
}

/// Phase-latency table from a campaign's telemetry snapshot: where a
/// download's wall time goes, phase by phase (the paper's "anatomy of
/// a transfer" rendered from measured spans instead of prose).
/// Quantiles come from the per-phase [`QuantileSketch`]s, so the table
/// costs O(buckets) regardless of session count; `Share` is each
/// phase's approximate total time over the sum across phases.
///
/// [`QuantileSketch`]: crate::util::stats::QuantileSketch
pub fn phase_latency_table(snap: &crate::telemetry::TelemetrySnapshot) -> Table {
    let mut t = Table::new(
        "Phase latency (per-session spans, sketch quantiles)",
        &[
            "Phase", "Spans", "p50 ms", "p95 ms", "p99 ms", "Max ms", "~Total s", "Share",
        ],
    );
    let grand_total: f64 = snap.phases.iter().map(|(_, sk)| sk.approx_sum()).sum();
    for (name, sk) in &snap.phases {
        if sk.is_empty() {
            continue;
        }
        let total = sk.approx_sum();
        t.row(vec![
            (*name).to_string(),
            sk.count().to_string(),
            format!("{:.3}", sk.quantile(0.5) * 1e3),
            format!("{:.3}", sk.quantile(0.95) * 1e3),
            format!("{:.3}", sk.quantile(0.99) * 1e3),
            format!("{:.3}", sk.max() * 1e3),
            format!("{total:.3}"),
            if grand_total > 0.0 {
                format!("{:.1}%", total / grand_total * 100.0)
            } else {
                "-".into()
            },
        ]);
    }
    t
}

/// Figures 6/7: per-filesize download speeds at one site, four bars
/// each (http cold/hot, stash cold/hot), Mbit/s, higher is better.
pub fn fig_site_performance(results: &ScenarioResults, site: &str) -> (String, Table) {
    let mut groups = Vec::new();
    let mut csv = Table::new(
        format!("{site} cache performance (Mbps)"),
        &["file", "http_cold", "http_hot", "stash_cold", "stash_hot"],
    );
    for (label, size) in defaults::test_file_sizes() {
        let get = |tool: &str, pass: &str| results.rate(site, &label, tool, pass).unwrap_or(0.0);
        let bars = vec![
            ("http cold".to_string(), get("http", "cold")),
            ("http hot".to_string(), get("http", "hot")),
            ("stash cold".to_string(), get("stash", "cold")),
            ("stash hot".to_string(), get("stash", "hot")),
        ];
        csv.row(vec![
            format!("{size}"),
            format!("{:.2}", bars[0].1),
            format!("{:.2}", bars[1].1),
            format!("{:.2}", bars[2].1),
            format!("{:.2}", bars[3].1),
        ]);
        groups.push((size.to_string(), bars));
    }
    let chart = grouped_bars(
        &format!("Figure ({site}): download speed by file size — higher is better"),
        &groups,
        "Mbps",
    );
    (chart, csv)
}

/// Figure 8: the 5.797 KB file across all five sites.
pub fn fig8_small_file(results: &ScenarioResults) -> (String, Table) {
    let mut groups = Vec::new();
    let mut csv = Table::new(
        "Small-file (5.797KB) performance (Mbps)",
        &["site", "http_cold", "http_hot", "stash_cold", "stash_hot"],
    );
    for site in COMPUTE_SITES {
        let get = |tool: &str, pass: &str| results.rate(site, "p01", tool, pass).unwrap_or(0.0);
        let bars = vec![
            ("http cold".to_string(), get("http", "cold")),
            ("http hot".to_string(), get("http", "hot")),
            ("stash cold".to_string(), get("stash", "cold")),
            ("stash hot".to_string(), get("stash", "hot")),
        ];
        csv.row(vec![
            site.to_string(),
            format!("{:.3}", bars[0].1),
            format!("{:.3}", bars[1].1),
            format!("{:.3}", bars[2].1),
            format!("{:.3}", bars[3].1),
        ]);
        groups.push((site.to_string(), bars));
    }
    let chart = grouped_bars(
        "Figure 8: 5.7KB download speed — HTTP proxy wins everywhere",
        &groups,
        "Mbps",
    );
    (chart, csv)
}

/// Figure 4: a year of federation usage, weekly.
pub fn fig4(days: f64, jobs_per_hour: f64) -> (String, Table) {
    let ucfg = UsageConfig {
        days,
        jobs_per_hour: Some(jobs_per_hour),
        // Usage volume, not contention, is Fig 4's subject — skip
        // background load so a year simulates in seconds.
        background_flows: 0,
        weekly_intensity: usage::fig4_weekly_intensity(),
        wan_bucket_secs: 6.0 * 3_600.0,
    };
    let mut out = usage::run(paper_federation(), &ucfg);
    let weekly = out.aggregator().weekly_series();
    let series: Vec<(String, f64)> = weekly
        .iter()
        .map(|(w, b)| (format!("week {w:02}"), b.as_f64() / 1e12))
        .collect();
    let chart = bar_chart("Figure 4: federation usage per week", &series, "TB");
    let mut csv = Table::new("Weekly usage", &["week", "bytes"]);
    for (w, b) in &weekly {
        csv.row(vec![w.to_string(), b.as_u64().to_string()]);
    }
    (chart, csv)
}

/// Figure 5: Syracuse WAN bandwidth before/after local cache install.
pub fn fig5(days: f64, jobs_per_hour: f64) -> (String, Table, usize) {
    let ucfg = UsageConfig {
        days,
        jobs_per_hour: Some(jobs_per_hour),
        background_flows: 1,
        weekly_intensity: Vec::new(),
        wan_bucket_secs: 1_800.0,
    };
    let (trace, install) = usage::fig5_before_after(paper_federation(), "syracuse", &ucfg);
    let mut csv = Table::new(
        "Syracuse WAN trace (30-min buckets)",
        &["bucket_start_s", "bytes", "phase"],
    );
    let mut series = Vec::new();
    for (i, (secs, bytes)) in trace.points().enumerate() {
        let phase = if i < install { "before" } else { "after" };
        csv.row(vec![format!("{secs:.0}"), bytes.to_string(), phase.into()]);
        let marker = if i == install { ">>" } else { "  " };
        series.push((
            format!("{marker}{:>6.1}h", secs / 3600.0),
            bytes as f64 * 8.0 / 1800.0 / 1e9, // Gbit/s average
        ));
    }
    let chart = bar_chart(
        "Figure 5: Syracuse WAN bandwidth (>> = cache installed)",
        &series,
        "Gbps",
    );
    (chart, csv, install)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_report_head_is_gwosc() {
        let ucfg = UsageConfig {
            days: 0.3,
            jobs_per_hour: Some(60.0),
            background_flows: 1,
            ..default_usage_cfg()
        };
        let (t, measured) = table1(&ucfg);
        // Tiny-scale runs are noisy; the head must be a top-share
        // experiment and the render must carry the paper column.
        assert!(
            measured[0].0 == "gwosc" || measured[0].0 == "des",
            "head: {measured:?}"
        );
        assert!(t.render().contains("1.079PB"));
    }

    #[test]
    fn table3_references_paper_cells() {
        // Rendering with an empty result set still shows paper values.
        let t = table3(&ScenarioResults::default());
        let s = t.render();
        assert!(s.contains("+506.5%"));
        assert!(s.contains("bellarmine"));
    }
}
