//! Report generation: the paper's tables and figures as text/CSV.
//!
//! Every bench target renders through here so `cargo bench`, the CLI
//! (`stashcache report`) and the examples produce identical artifacts.
//! Figures are emitted both as aligned ASCII (for terminals and
//! EXPERIMENTS.md) and CSV (for replotting).

pub mod paper;

use std::fmt::Write as _;

/// A simple aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            writeln!(out, "== {} ==", self.title).unwrap();
        }
        let line = |out: &mut String, cells: &[String]| {
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                write!(out, "{cell:>width$}", width = widths[i]).unwrap();
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let rule: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        writeln!(out, "{}", "-".repeat(rule)).unwrap();
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Render as a GitHub-flavoured markdown table (title as a
    /// heading, pipes escaped).
    pub fn to_markdown(&self) -> String {
        let esc = |s: &String| s.replace('|', "\\|");
        let mut out = String::new();
        if !self.title.is_empty() {
            writeln!(out, "## {}\n", self.title).unwrap();
        }
        writeln!(
            out,
            "| {} |",
            self.headers.iter().map(esc).collect::<Vec<_>>().join(" | ")
        )
        .unwrap();
        writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| " --- ").collect::<Vec<_>>().join("|")
        )
        .unwrap();
        for row in &self.rows {
            writeln!(out, "| {} |", row.iter().map(esc).collect::<Vec<_>>().join(" | ")).unwrap();
        }
        out
    }

    /// Render as CSV (headers + rows, comma-separated, quoted as
    /// needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        writeln!(out, "{}", self.headers.iter().map(esc).collect::<Vec<_>>().join(",")).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(",")).unwrap();
        }
        out
    }
}

/// An ASCII bar chart (horizontal), for figure-style series.
pub fn bar_chart(title: &str, series: &[(String, f64)], unit: &str) -> String {
    let mut out = String::new();
    writeln!(out, "== {title} ==").unwrap();
    let max = series.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = series.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    const WIDTH: usize = 48;
    for (label, value) in series {
        let bar = if max > 0.0 {
            ((value / max) * WIDTH as f64).round() as usize
        } else {
            0
        };
        writeln!(
            out,
            "{label:>label_w$} | {} {value:.2} {unit}",
            "#".repeat(bar.min(WIDTH)),
        )
        .unwrap();
    }
    out
}

/// Grouped bars per category (Figures 6-8: four bars per file size).
pub fn grouped_bars(
    title: &str,
    groups: &[(String, Vec<(String, f64)>)],
    unit: &str,
) -> String {
    let mut out = String::new();
    writeln!(out, "== {title} ==").unwrap();
    let max = groups
        .iter()
        .flat_map(|(_, bars)| bars.iter().map(|(_, v)| *v))
        .fold(f64::MIN, f64::max);
    let label_w = groups
        .iter()
        .flat_map(|(_, bars)| bars.iter().map(|(l, _)| l.len()))
        .max()
        .unwrap_or(0);
    const WIDTH: usize = 42;
    for (group, bars) in groups {
        writeln!(out, "{group}:").unwrap();
        for (label, value) in bars {
            let bar = if max > 0.0 {
                ((value / max) * WIDTH as f64).round() as usize
            } else {
                0
            };
            writeln!(
                out,
                "  {label:>label_w$} | {} {value:.2} {unit}",
                "#".repeat(bar.min(WIDTH)),
            )
            .unwrap();
        }
    }
    out
}

/// Write a report artifact (text or CSV) under a directory.
pub fn write_artifact(dir: &std::path::Path, name: &str, content: &str) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(name), content)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Usage", &["Experiment", "Usage"]);
        t.row(vec!["gwosc".into(), "1.079PB".into()]);
        t.row(vec!["des".into(), "709.051TB".into()]);
        let s = t.render();
        assert!(s.contains("== Usage =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        // Right-aligned columns: all rows end at the same width.
        assert_eq!(lines[2].len(), lines[3].len().max(lines[2].len()));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn markdown_renders_header_rule_and_escapes() {
        let mut t = Table::new("Frontier", &["Cell", "winner"]);
        t.row(vec!["a|b".into(), "stash".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("## Frontier"));
        assert!(md.contains("| Cell | winner |"));
        assert!(md.contains("| --- | --- |"));
        assert!(md.contains("a\\|b"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            "t",
            &[("a".into(), 10.0), ("b".into(), 5.0), ("c".into(), 0.0)],
            "MB/s",
        );
        let a_bar = s.lines().nth(1).unwrap().matches('#').count();
        let b_bar = s.lines().nth(2).unwrap().matches('#').count();
        assert_eq!(a_bar, 48);
        assert_eq!(b_bar, 24);
    }

    #[test]
    fn grouped_bars_renders_all() {
        let s = grouped_bars(
            "fig",
            &[
                ("5.797KB".into(), vec![("http cold".into(), 1.0), ("stash cold".into(), 0.5)]),
                ("10GB".into(), vec![("http cold".into(), 2.0)]),
            ],
            "Mbps",
        );
        assert!(s.contains("5.797KB:"));
        assert!(s.contains("stash cold"));
    }
}
