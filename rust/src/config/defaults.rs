//! Built-in federation description reproducing the paper's testbed.
//!
//! * **Cache deployment** (Figure 2): caches at six universities
//!   (Syracuse, Nebraska, Chicago, UCSD, Caltech, Florida), three
//!   Internet2 PoPs (New York, Kansas City, Houston) and the
//!   University of Amsterdam — ten caches total, real coordinates.
//! * **Compute sites** (§4.1): "the top 5 sites providing opportunistic
//!   computing": Syracuse, Colorado, Bellarmine, Nebraska, Chicago.
//! * **Origin**: the test dataset "was hosted on the Stash filesystem
//!   at the University of Chicago" (§4.1); production origins for each
//!   experiment also live there in this reproduction.
//!
//! Link profiles are *calibrated*, not measured: the paper gives no
//! bandwidth tables, so per-site numbers were tuned until the shape of
//! Figures 6-8 and Table 3 matched (see EXPERIMENTS.md). The defining
//! features are taken from the paper's own explanations:
//!   * Colorado "prioritize[s] bandwidth to the HTTP proxy" and its
//!     workers have "slower networking to the nearest StashCache
//!     cache" (§5) — it has no local cache, a fat proxy path, and a
//!     thin worker WAN path.
//!   * Syracuse/Nebraska/Chicago host local caches on the worker LAN.
//!   * Bellarmine is a small site whose proxy WAN path is thin, while
//!     the nearest I2 cache is well connected.

use super::schema::*;
use crate::util::bytes::{ByteSize, GB, KB, MB};

/// Names of the five compute sites the paper tested (§4.1), in the
/// order of Table 3.
pub const COMPUTE_SITES: [&str; 5] = [
    "bellarmine",
    "syracuse",
    "colorado",
    "nebraska",
    "chicago",
];

/// The eight test file sizes of §4.1 (Table 2 percentiles minus the
/// duplicate 99th, plus the forward-looking 10 GB file).
pub fn test_file_sizes() -> Vec<(String, ByteSize)> {
    vec![
        ("p01".into(), ByteSize(5_797)),                    // 5.797 KB
        ("p05".into(), ByteSize::from_f64(22.801, MB)),     // 22.801 MB
        ("p25".into(), ByteSize::from_f64(170.131, MB)),    // 170.131 MB
        ("p50".into(), ByteSize::from_f64(467.852, MB)),    // 467.852 MB
        ("p75".into(), ByteSize::from_f64(493.337, MB)),    // 493.337 MB
        ("p95".into(), ByteSize::from_f64(2.335, GB)),      // 2.335 GB
        ("f10g".into(), ByteSize::gb(10)),                  // 10 GB
    ]
}

/// Full paper federation: 12 sites (5 compute, 10 caches, 3 overlap),
/// one origin per experiment at Chicago.
pub fn paper_federation() -> FederationConfig {
    let mut sites = Vec::new();

    // --- compute sites (§4.1) --------------------------------------------
    // Syracuse: hosts a local cache on the worker LAN ("installed a
    // cache locally to minimize outbound requests", §4). StashCache
    // wins for large files here (Fig 7, Table 3: 10GB -26.3%).
    sites.push(SiteConfig {
        name: "syracuse".into(),
        lat: 43.0392,
        lon: -76.1351,
        worker_slots: 64,
        links: LinkProfile {
            wan_gbps: 10.0,
            proxy_lan_gbps: 10.0,
            proxy_wan_gbps: 10.0,
            worker_wan_gbps: 5.0,
            cache_lan_gbps: 10.0,
            cache_wan_gbps: 10.0,
            lan_rtt_ms: 0.3,
        },
        proxy: Some(ProxyConfig {
            per_conn_gbps: 1.1,
            ..ProxyConfig::default()
        }),
        // University-host cache: single-client delivery tops out near
        // the proxy's (old storage host) — calibrated so 2.3 GB is a
        // near-tie with the proxy (Table 3: +0.9%).
        cache: Some(CacheConfig {
            per_conn_gbps: 1.0,
            ..CacheConfig::default()
        }),
    });

    // Colorado: the paper's outlier. No local cache; proxy path is
    // heavily provisioned while the worker WAN path is thin, so HTTP
    // wins at every file size (Fig 6, Table 3: +506%/+246%).
    sites.push(SiteConfig {
        name: "colorado".into(),
        lat: 40.0076,
        lon: -105.2659,
        worker_slots: 48,
        links: LinkProfile {
            wan_gbps: 40.0,
            proxy_lan_gbps: 40.0,
            proxy_wan_gbps: 40.0,
            worker_wan_gbps: 1.0,
            cache_lan_gbps: 10.0, // unused (no local cache)
            cache_wan_gbps: 10.0,
            lan_rtt_ms: 0.3,
        },
        proxy: Some(ProxyConfig {
            per_conn_gbps: 6.0,
            ..ProxyConfig::default()
        }),
        cache: None,
    });

    // Bellarmine: small site, thin shared proxy/WAN path; the nearest
    // I2 cache is comparatively well connected, so StashCache wins
    // decisively at 2.3 GB (-68.5%).
    sites.push(SiteConfig {
        name: "bellarmine".into(),
        lat: 38.2186,
        lon: -85.7123,
        worker_slots: 16,
        links: LinkProfile {
            wan_gbps: 3.0,
            proxy_lan_gbps: 1.0,
            proxy_wan_gbps: 1.0,
            worker_wan_gbps: 3.0,
            cache_lan_gbps: 10.0, // unused (no local cache)
            cache_wan_gbps: 10.0,
            lan_rtt_ms: 0.4,
        },
        proxy: Some(ProxyConfig {
            per_conn_gbps: 0.35,
            ..ProxyConfig::default()
        }),
        cache: None,
    });

    // Nebraska: local cache; StashCache modestly ahead for large files
    // (Table 3: -12.1% / -2.1%).
    sites.push(SiteConfig {
        name: "nebraska".into(),
        lat: 40.8202,
        lon: -96.7005,
        worker_slots: 96,
        links: LinkProfile {
            wan_gbps: 100.0,
            proxy_lan_gbps: 10.0,
            proxy_wan_gbps: 10.0,
            worker_wan_gbps: 10.0,
            cache_lan_gbps: 10.0,
            cache_wan_gbps: 10.0,
            lan_rtt_ms: 0.2,
        },
        proxy: Some(ProxyConfig {
            per_conn_gbps: 1.6,
            ..ProxyConfig::default()
        }),
        cache: Some(CacheConfig {
            per_conn_gbps: 1.6,
            ..CacheConfig::default()
        }),
    });

    // Chicago: local cache *and* the origin is on campus, so the HTTP
    // path to the origin is short and fast; proxy wins at 2.3 GB
    // (+30.6%) but loses at 10 GB (-7.7%).
    sites.push(SiteConfig {
        name: "chicago".into(),
        lat: 41.7886,
        lon: -87.5987,
        worker_slots: 64,
        links: LinkProfile {
            wan_gbps: 100.0,
            proxy_lan_gbps: 10.0,
            proxy_wan_gbps: 20.0,
            worker_wan_gbps: 8.0,
            cache_lan_gbps: 10.0,
            cache_wan_gbps: 10.0,
            lan_rtt_ms: 0.2,
        },
        proxy: Some(ProxyConfig {
            per_conn_gbps: 2.2,
            ..ProxyConfig::default()
        }),
        cache: Some(CacheConfig {
            per_conn_gbps: 1.5,
            ..CacheConfig::default()
        }),
    });

    // --- cache-only sites (Figure 2) --------------------------------------
    let cache_only: [(&str, f64, f64); 7] = [
        ("ucsd", 32.8801, -117.2340),
        ("caltech", 34.1377, -118.1253),
        ("florida", 29.6436, -82.3549),
        ("i2-newyork", 40.7128, -74.0060),
        ("i2-kansascity", 39.0997, -94.5786),
        ("i2-houston", 29.7604, -95.3698),
        ("amsterdam", 52.3676, 4.9041),
    ];
    for (name, lat, lon) in cache_only {
        sites.push(SiteConfig {
            name: name.into(),
            lat,
            lon,
            worker_slots: 0,
            links: LinkProfile {
                // Paper §1: caches are "guaranteed to have at least
                // 10Gbps networking and several TB's of caching
                // storage"; I2 PoPs sit on the backbone.
                wan_gbps: if name.starts_with("i2-") { 100.0 } else { 10.0 },
                ..LinkProfile::default()
            },
            proxy: None,
            cache: Some(CacheConfig::default()),
        });
    }

    // --- origins -----------------------------------------------------------
    // The test dataset and all experiment origins live on the Stash
    // filesystem at Chicago (§4.1). One origin prefix per experiment.
    let mut origins = vec![OriginConfig {
        name: "stash-chicago".into(),
        site: "chicago".into(),
        prefix: "/osgconnect/public".into(),
    }];
    for e in paper_workload().experiments {
        origins.push(OriginConfig {
            name: format!("origin-{}", e.name),
            site: "chicago".into(),
            prefix: format!("/ospool/{}", e.name),
        });
    }

    FederationConfig {
        name: "osg-stashcache".into(),
        seed: 20190728, // PEARC '19 started July 28
        redirector_instances: 2,
        redirection: RedirectionConfig::default(),
        resilience: ResilienceConfig::default(),
        sites,
        origins,
        workload: paper_workload(),
    }
}

/// Workload mix from Table 1 (top users, 6 months ending Feb 2019).
/// Shares are the paper's byte totals.
pub fn paper_workload() -> WorkloadConfig {
    let experiments = [
        ("gwosc", 1_079_000.0),        // Open Gravitational Wave Research, 1.079 PB
        ("des", 709_051.0),            // Dark Energy Survey, 709.051 TB
        ("minerva", 514_794.0),        // MINERvA, 514.794 TB
        ("ligo", 228_324.0),           // LIGO, 228.324 TB
        ("osg-testing", 184_773.0),    // Continuous Testing, 184.773 TB
        ("nova", 24_317.0),            // NOvA, 24.317 TB
        ("lsst", 18_966.0),            // LSST, 18.966 TB
        ("bioinformatics", 17_566.0),  // Bioinformatics, 17.566 TB
        ("dune", 11_677.0),            // DUNE, 11.677 TB
    ]
    .into_iter()
    .map(|(name, share)| ExperimentMix {
        name: name.to_string(),
        share,
    })
    .collect();

    WorkloadConfig {
        experiments,
        // Scientific working sets are heavily reused (LIGO jobs share
        // frame files); a skewed Zipf over a few thousand hot files is
        // what makes the caches effective (Fig 5's 9× WAN drop).
        zipf_s: 1.2,
        files_per_experiment: 5_000,
        size_dist: paper_size_distribution(),
        jobs_per_hour: 1_200.0,
        files_per_job: (1, 6),
    }
}

/// Log-normal mixture fitted to the Table 2 file-size percentiles:
///
/// | pct | paper      |
/// |-----|------------|
/// |  1  | 5.797 KB   |
/// |  5  | 22.801 MB  |
/// | 25  | 170.131 MB |
/// | 50  | 467.852 MB |
/// | 75  | 493.337 MB |
/// | 95  | 2.335 GB   |
/// | 99  | 2.335 GB   |
///
/// Three components: a small-file tail (logs, JSON), a dominant
/// ~470-490 MB mode (the 50th and 75th percentiles nearly coincide —
/// frame files), and a multi-GB analysis-dataset mode that saturates
/// near 2.335 GB (95th == 99th percentile in the paper, suggesting a
/// hard popular-file size). Verified by `table2_percentiles`.
pub fn paper_size_distribution() -> SizeDistribution {
    SizeDistribution {
        components: vec![
            // ~2% tiny files (logs/JSON) centred at the 1st-pctile 6 KB.
            (0.02, (6.0 * KB as f64).ln(), 1.5),
            // ~26% small-to-medium spanning p5 (22.8 MB) → p25 (170 MB).
            (0.26, (62.0 * MB as f64).ln(), 0.84),
            // ~62% the dominant ~476 MB mode (p50 ≈ p75), narrow.
            (0.62, (476.0 * MB as f64).ln(), 0.05),
            // ~10% large analysis files pinned at 2.335 GB (p95 == p99).
            (0.10, (2.335 * GB as f64).ln(), 0.02),
        ],
        min: ByteSize(512),
        max: ByteSize::gb(10),
    }
}

/// An example TOML config equivalent to a trimmed `paper_federation()`;
/// written by `stashcache init-config` and parsed in tests to keep the
/// parser and the builder honest with each other.
pub fn example_toml() -> String {
    r#"# StashCache federation config (subset of the built-in paper topology)
[federation]
name = "osg-stashcache"
seed = 20190728
redirector_instances = 2

# Cache-selection policy: nearest | least-loaded | consistent-hash | tiered
[redirection]
policy = "nearest"
nearest_k = 3
virtual_nodes = 64
regional_km = 2000.0
location_cache_cap = 65536

# Failover ladder + gray-failure defence. The defaults reproduce the
# pre-breaker engine exactly: deadline_factor = 0 arms no transfer
# deadlines and breaker = false never ejects a cache.
[resilience]
max_failover_retries = 3
direct_retry_backoff_secs = 2.0
deadline_factor = 0.0
breaker = false
breaker_alpha = 0.3
breaker_threshold = 0.5
breaker_cooldown_secs = 30.0

[[site]]
name = "syracuse"
lat = 43.0392
lon = -76.1351
worker_slots = 64
[site.links]
wan_gbps = 10.0
proxy_lan_gbps = 10.0
proxy_wan_gbps = 10.0
worker_wan_gbps = 5.0
cache_lan_gbps = 10.0
cache_wan_gbps = 10.0
lan_rtt_ms = 0.3
[site.proxy]
capacity = "100GB"
max_object = "1GB"
ttl_secs = 3600.0
per_conn_gbps = 1.1
[site.cache]
capacity = "8TB"
high_watermark = 0.95
low_watermark = 0.85
chunk_size = "24MB"
per_conn_gbps = 8.0

[[site]]
name = "chicago"
lat = 41.7886
lon = -87.5987
worker_slots = 64
[site.proxy]
capacity = "100GB"
[site.cache]
capacity = "8TB"

[[origin]]
name = "stash-chicago"
site = "chicago"
prefix = "/osgconnect/public"
"#
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_federation_shape() {
        let cfg = paper_federation();
        cfg.validate().unwrap();
        assert_eq!(cfg.sites.len(), 12);
        assert_eq!(cfg.cache_sites().count(), 10, "Fig 2: ten caches");
        assert_eq!(cfg.compute_sites().count(), 5, "§4.1: five test sites");
        // The three overlap sites host both workers and caches.
        for name in ["syracuse", "nebraska", "chicago"] {
            let s = cfg.site(name).unwrap();
            assert!(s.cache.is_some() && s.worker_slots > 0, "{name}");
        }
        for name in ["colorado", "bellarmine"] {
            assert!(cfg.site(name).unwrap().cache.is_none(), "{name}");
        }
    }

    #[test]
    fn origins_cover_experiments() {
        let cfg = paper_federation();
        for e in &cfg.workload.experiments {
            assert!(
                cfg.origins
                    .iter()
                    .any(|o| o.prefix == format!("/ospool/{}", e.name)),
                "origin for {}",
                e.name
            );
        }
    }

    #[test]
    fn table1_order_preserved() {
        let w = paper_workload();
        let shares: Vec<f64> = w.experiments.iter().map(|e| e.share).collect();
        let mut sorted = shares.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(shares, sorted, "Table 1 is sorted by usage");
        assert_eq!(w.experiments[0].name, "gwosc");
        assert!((w.experiments[0].share / w.experiments[8].share - 92.4).abs() < 0.5);
    }

    #[test]
    fn size_distribution_weights_sum_to_one() {
        let d = paper_size_distribution();
        let total: f64 = d.components.iter().map(|c| c.0).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn test_files_match_table2() {
        let files = test_file_sizes();
        assert_eq!(files.len(), 7, "99th == 95th, so 6 percentile files + 10GB");
        assert_eq!(files[0].1, ByteSize(5_797));
        assert_eq!(files[5].1, ByteSize(2_335_000_000));
        assert_eq!(files[6].1, ByteSize::gb(10));
    }

    #[test]
    fn example_toml_parses() {
        let cfg = FederationConfig::from_toml(&example_toml()).unwrap();
        assert_eq!(cfg.name, "osg-stashcache");
        assert_eq!(cfg.sites.len(), 2);
        assert_eq!(
            cfg.site("syracuse").unwrap().proxy.unwrap().per_conn_gbps,
            1.1
        );
    }
}
