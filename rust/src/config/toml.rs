//! Minimal TOML parser (offline substitute for the `toml` crate).
//!
//! Supported subset — everything the federation configs use:
//! * key/value pairs: strings (`"..."`), integers, floats, booleans
//! * bare and quoted keys, dotted table headers `[a.b]`
//! * arrays of scalars `[1, 2, 3]` (homogeneity not enforced)
//! * arrays of tables `[[site]]`
//! * comments (`#`) and blank lines
//!
//! Not supported (and rejected, not silently misparsed): multi-line
//! strings, datetimes, inline tables, dotted keys on the left-hand side.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
    Table(Table),
}

pub type Table = BTreeMap<String, Value>;

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`bandwidth = 10`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&Table> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    /// Navigate `a.b.c` through nested tables.
    pub fn get_path(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }
}

/// Parse error with 1-based line number.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError {
        line,
        msg: msg.into(),
    })
}

/// Parse a complete document into the root table.
pub fn parse(input: &str) -> Result<Table, ParseError> {
    let mut root = Table::new();
    // Path of the table currently being filled.
    let mut current: Vec<String> = Vec::new();

    for (idx, raw) in input.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix("[[") {
            let Some(name) = header.strip_suffix("]]") else {
                return err(line_no, "unterminated [[table]] header");
            };
            let path = parse_key_path(name, line_no)?;
            push_array_table(&mut root, &path, line_no)?;
            current = path;
        } else if let Some(header) = line.strip_prefix('[') {
            let Some(name) = header.strip_suffix(']') else {
                return err(line_no, "unterminated [table] header");
            };
            let path = parse_key_path(name, line_no)?;
            ensure_table(&mut root, &path, line_no)?;
            current = path;
        } else {
            let Some(eq) = find_top_level_eq(line) else {
                return err(line_no, format!("expected key = value, got {line:?}"));
            };
            let key = parse_key(line[..eq].trim(), line_no)?;
            let value = parse_value(line[eq + 1..].trim(), line_no)?;
            let table = navigate_mut(&mut root, &current, line_no)?;
            if table.contains_key(&key) {
                return err(line_no, format!("duplicate key {key:?}"));
            }
            table.insert(key, value);
        }
    }
    Ok(root)
}

/// Strip a `#` comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Find the first `=` outside quotes.
fn find_top_level_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_key(s: &str, line: usize) -> Result<String, ParseError> {
    if let Some(q) = s.strip_prefix('"') {
        let Some(name) = q.strip_suffix('"') else {
            return err(line, "unterminated quoted key");
        };
        return Ok(name.to_string());
    }
    if s.is_empty()
        || !s
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    {
        return err(line, format!("invalid bare key {s:?}"));
    }
    Ok(s.to_string())
}

fn parse_key_path(s: &str, line: usize) -> Result<Vec<String>, ParseError> {
    s.split('.')
        .map(|part| parse_key(part.trim(), line))
        .collect()
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    if s.is_empty() {
        return err(line, "missing value");
    }
    if let Some(q) = s.strip_prefix('"') {
        let Some(body) = q.strip_suffix('"') else {
            return err(line, "unterminated string");
        };
        return Ok(Value::Str(unescape(body, line)?));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        let Some(body) = s[1..].strip_suffix(']') else {
            return err(line, "unterminated array (arrays must be single-line)");
        };
        let mut items = Vec::new();
        for part in split_array_items(body) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    // Numbers: underscores allowed as separators.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        if f.is_finite() {
            return Ok(Value::Float(f));
        }
    }
    err(line, format!("cannot parse value {s:?}"))
}

fn unescape(s: &str, line: usize) -> Result<String, ParseError> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return err(line, format!("bad escape \\{other:?}")),
        }
    }
    Ok(out)
}

/// Split array body on commas not inside quotes or nested brackets.
fn split_array_items(body: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                items.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&body[start..]);
    items
}

fn ensure_table<'a>(
    root: &'a mut Table,
    path: &[String],
    line: usize,
) -> Result<&'a mut Table, ParseError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Value::Table(Table::new()));
        cur = match entry {
            Value::Table(t) => t,
            Value::Array(items) => match items.last_mut() {
                Some(Value::Table(t)) => t,
                _ => return err(line, format!("{part:?} is not a table")),
            },
            _ => return err(line, format!("{part:?} is not a table")),
        };
    }
    Ok(cur)
}

fn push_array_table(root: &mut Table, path: &[String], line: usize) -> Result<(), ParseError> {
    let (last, parents) = path.split_last().expect("non-empty path");
    let parent = ensure_table(root, parents, line)?;
    match parent
        .entry(last.clone())
        .or_insert_with(|| Value::Array(Vec::new()))
    {
        Value::Array(items) => {
            items.push(Value::Table(Table::new()));
            Ok(())
        }
        _ => err(line, format!("{last:?} is not an array of tables")),
    }
}

fn navigate_mut<'a>(
    root: &'a mut Table,
    path: &[String],
    line: usize,
) -> Result<&'a mut Table, ParseError> {
    ensure_table(root, path, line)
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Table(t) => {
                write!(f, "{{")?;
                for (i, (k, v)) in t.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k} = {v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let t = parse(
            r#"
            name = "syracuse"
            cores = 48
            bw = 10.5
            enabled = true
            neg = -3
            big = 1_000_000
        "#,
        )
        .unwrap();
        assert_eq!(t["name"].as_str(), Some("syracuse"));
        assert_eq!(t["cores"].as_int(), Some(48));
        assert_eq!(t["bw"].as_float(), Some(10.5));
        assert_eq!(t["enabled"].as_bool(), Some(true));
        assert_eq!(t["neg"].as_int(), Some(-3));
        assert_eq!(t["big"].as_int(), Some(1_000_000));
    }

    #[test]
    fn int_coerces_to_float() {
        let t = parse("x = 10").unwrap();
        assert_eq!(t["x"].as_float(), Some(10.0));
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = parse("# header\n\na = 1 # trailing\nb = \"#notcomment\"\n").unwrap();
        assert_eq!(t["a"].as_int(), Some(1));
        assert_eq!(t["b"].as_str(), Some("#notcomment"));
    }

    #[test]
    fn nested_tables() {
        let t = parse("[federation]\nseed = 7\n[federation.monitoring]\nport = 9930\n").unwrap();
        assert_eq!(t.get("federation").unwrap().get_path("seed").unwrap(), &Value::Int(7));
        assert_eq!(
            t["federation"].get_path("monitoring.port"),
            Some(&Value::Int(9930))
        );
    }

    #[test]
    fn arrays() {
        let t = parse("sizes = [1, 2, 3]\nnames = [\"a\", \"b\"]\nempty = []\n").unwrap();
        assert_eq!(
            t["sizes"].as_array().unwrap(),
            &[Value::Int(1), Value::Int(2), Value::Int(3)]
        );
        assert_eq!(t["names"].as_array().unwrap().len(), 2);
        assert!(t["empty"].as_array().unwrap().is_empty());
    }

    #[test]
    fn array_with_string_commas() {
        let t = parse(r#"x = ["a,b", "c"]"#).unwrap();
        assert_eq!(t["x"].as_array().unwrap().len(), 2);
    }

    #[test]
    fn arrays_of_tables() {
        let doc = r#"
            [[site]]
            name = "syracuse"
            [site.links]
            wan = 10.0
            [[site]]
            name = "colorado"
        "#;
        let t = parse(doc).unwrap();
        let sites = t["site"].as_array().unwrap();
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].get_path("name").unwrap().as_str(), Some("syracuse"));
        assert_eq!(sites[0].get_path("links.wan").unwrap().as_float(), Some(10.0));
        assert_eq!(sites[1].get_path("name").unwrap().as_str(), Some("colorado"));
    }

    #[test]
    fn string_escapes() {
        let t = parse(r#"s = "line1\nline2\t\"q\" \\" "#).unwrap();
        assert_eq!(t["s"].as_str(), Some("line1\nline2\t\"q\" \\"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("a = 1\nb =\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("a = 1\n[bad\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("x = zzz").unwrap_err();
        assert!(e.msg.contains("cannot parse"));
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn quoted_keys() {
        let t = parse("\"weird key\" = 5\n").unwrap();
        assert_eq!(t["weird key"].as_int(), Some(5));
    }
}
