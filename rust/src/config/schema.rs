//! Typed configuration schema with validation.
//!
//! Maps the parsed TOML tree ([`super::toml`]) onto the structs the
//! federation builder consumes. Every numeric field is validated at
//! load time so a bad config fails before a multi-hour simulation
//! starts.

use super::toml::{self, Table, Value};
use crate::redirector::policy::PolicyKind;
use crate::util::ByteSize;
use anyhow::{anyhow, bail, Context, Result};

/// Top-level federation description.
#[derive(Debug, Clone)]
pub struct FederationConfig {
    /// Run name (report headers).
    pub name: String,
    /// Master RNG seed; every component forks a stream from it.
    pub seed: u64,
    /// Number of redirector instances in the round-robin HA pool
    /// (the OSG runs two — paper §3).
    pub redirector_instances: usize,
    /// Cache-selection policy and redirector tuning.
    pub redirection: RedirectionConfig,
    /// Failover ladder, transfer deadlines, and the cache circuit
    /// breaker.
    pub resilience: ResilienceConfig,
    /// One entry per site (compute sites, cache sites, or both).
    pub sites: Vec<SiteConfig>,
    /// Data origins and their namespace prefixes.
    pub origins: Vec<OriginConfig>,
    /// Workload description for the usage simulations.
    pub workload: WorkloadConfig,
}

/// Redirection-layer tuning: which cache-selection policy the
/// federation runs ([`crate::redirector::policy`]) and the redirector's
/// location-cache bound. Parsed from the `[redirection]` TOML table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RedirectionConfig {
    /// Cache-selection policy (default: the paper's GeoIP nearest).
    pub policy: PolicyKind,
    /// `least-loaded`: how many nearest candidates compete on live
    /// load (≥ 1; 1 degenerates to `nearest`).
    pub nearest_k: usize,
    /// `consistent-hash`: virtual nodes per cache on the ring (≥ 1).
    pub virtual_nodes: usize,
    /// `tiered`: radius of the regional ring in km (> 0); beyond it a
    /// session streams from the origin instead of a WAN cache.
    pub regional_km: f64,
    /// Redirector location-cache LRU bound, entries (≥ 1).
    pub location_cache_cap: usize,
}

impl Default for RedirectionConfig {
    fn default() -> Self {
        RedirectionConfig {
            policy: PolicyKind::Nearest,
            nearest_k: 3,
            virtual_nodes: 64,
            regional_km: 2_000.0,
            location_cache_cap: crate::redirector::DEFAULT_LOCATION_CACHE_CAP,
        }
    }
}

impl RedirectionConfig {
    /// Parse a `[redirection]` table. Strict like the sweep grid:
    /// unknown keys, wrong types, and out-of-range values are errors —
    /// never silently replaced by defaults.
    pub fn from_table(t: &Table) -> Result<Self> {
        const KNOWN_KEYS: [&str; 5] = [
            "policy", "nearest_k", "virtual_nodes", "regional_km", "location_cache_cap",
        ];
        for key in t.keys() {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                bail!(
                    "unknown key {key:?} in [redirection] (known: {})",
                    KNOWN_KEYS.join(", ")
                );
            }
        }
        let mut r = RedirectionConfig::default();
        if let Some(v) = t.get("policy") {
            let name = v
                .as_str()
                .ok_or_else(|| anyhow!("redirection policy must be a string"))?;
            r.policy = PolicyKind::from_name(name).ok_or_else(|| {
                anyhow!(
                    "unknown redirection policy {name:?} ({})",
                    crate::redirector::POLICY_NAMES
                )
            })?;
        }
        let uint = |v: &Value, what: &str| -> Result<usize> {
            let i = v
                .as_int()
                .ok_or_else(|| anyhow!("{what} must be an integer"))?;
            if i < 1 {
                bail!("{what} must be >= 1, got {i}");
            }
            Ok(i as usize)
        };
        if let Some(v) = t.get("nearest_k") {
            r.nearest_k = uint(v, "nearest_k")?;
        }
        if let Some(v) = t.get("virtual_nodes") {
            r.virtual_nodes = uint(v, "virtual_nodes")?;
        }
        if let Some(v) = t.get("regional_km") {
            r.regional_km = v
                .as_float()
                .ok_or_else(|| anyhow!("regional_km must be numeric"))?;
        }
        if let Some(v) = t.get("location_cache_cap") {
            r.location_cache_cap = uint(v, "location_cache_cap")?;
        }
        r.validate()?;
        Ok(r)
    }

    pub fn validate(&self) -> Result<()> {
        if self.nearest_k == 0 {
            bail!("redirection nearest_k must be >= 1");
        }
        if self.virtual_nodes == 0 {
            bail!("redirection virtual_nodes must be >= 1");
        }
        if !(self.regional_km > 0.0 && self.regional_km.is_finite()) {
            bail!(
                "redirection regional_km must be positive and finite, got {}",
                self.regional_km
            );
        }
        if self.location_cache_cap == 0 {
            bail!("redirection location_cache_cap must be >= 1");
        }
        Ok(())
    }
}

/// Resilience tuning: the failover ladder the session engine walks on
/// faults and timeouts, the per-transfer progress deadline, and the
/// per-cache circuit breaker. Parsed from the `[resilience]` TOML
/// table. The defaults reproduce the pre-breaker engine exactly:
/// `deadline_factor = 0` arms no timers and `breaker = false` keeps
/// every cache admitted, so no-fault runs stay byte-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResilienceConfig {
    /// Failovers a session attempts before giving up on the cache
    /// federation and streaming directly from the origin (≥ 1).
    pub max_failover_retries: u32,
    /// Backoff between direct-path connection retries while an origin
    /// route is down, seconds (> 0).
    pub direct_retry_backoff_secs: f64,
    /// Transfer-deadline multiplier: a session in a cache transfer (or
    /// parked on a join) fails over after `expected_time × factor`
    /// without completing. `0` disables deadlines (the default —
    /// pre-deadline behavior bit-for-bit); enabled values must be
    /// ≥ 1 so a healthy transfer can always beat its own deadline.
    pub deadline_factor: f64,
    /// Master switch for the per-cache circuit breaker.
    pub breaker: bool,
    /// EWMA weight of the newest outcome in the health score (0, 1].
    pub breaker_alpha: f64,
    /// Health score at which a closed breaker trips open (0, 1): the
    /// score is the EWMA of failure indicators, so higher = sicker.
    pub breaker_threshold: f64,
    /// Seconds an open breaker ejects its cache before admitting the
    /// half-open probe session (> 0).
    pub breaker_cooldown_secs: f64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            max_failover_retries: crate::fault::MAX_FAILOVER_RETRIES,
            direct_retry_backoff_secs: crate::fault::DIRECT_RETRY_BACKOFF.as_secs_f64(),
            deadline_factor: 0.0,
            breaker: false,
            breaker_alpha: 0.3,
            breaker_threshold: 0.5,
            breaker_cooldown_secs: 30.0,
        }
    }
}

impl ResilienceConfig {
    /// Parse a `[resilience]` table. Strict like `[redirection]`:
    /// unknown keys, wrong types, and out-of-range values are errors.
    pub fn from_table(t: &Table) -> Result<Self> {
        const KNOWN_KEYS: [&str; 7] = [
            "max_failover_retries",
            "direct_retry_backoff_secs",
            "deadline_factor",
            "breaker",
            "breaker_alpha",
            "breaker_threshold",
            "breaker_cooldown_secs",
        ];
        for key in t.keys() {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                bail!(
                    "unknown key {key:?} in [resilience] (known: {})",
                    KNOWN_KEYS.join(", ")
                );
            }
        }
        let mut r = ResilienceConfig::default();
        if let Some(v) = t.get("max_failover_retries") {
            let i = v
                .as_int()
                .ok_or_else(|| anyhow!("max_failover_retries must be an integer"))?;
            if i < 1 {
                bail!("max_failover_retries must be >= 1, got {i}");
            }
            r.max_failover_retries = i as u32;
        }
        let float = |v: &Value, what: &str| -> Result<f64> {
            v.as_float()
                .ok_or_else(|| anyhow!("{what} must be numeric"))
        };
        if let Some(v) = t.get("direct_retry_backoff_secs") {
            r.direct_retry_backoff_secs = float(v, "direct_retry_backoff_secs")?;
        }
        if let Some(v) = t.get("deadline_factor") {
            r.deadline_factor = float(v, "deadline_factor")?;
        }
        if let Some(v) = t.get("breaker") {
            r.breaker = v
                .as_bool()
                .ok_or_else(|| anyhow!("breaker must be a boolean"))?;
        }
        if let Some(v) = t.get("breaker_alpha") {
            r.breaker_alpha = float(v, "breaker_alpha")?;
        }
        if let Some(v) = t.get("breaker_threshold") {
            r.breaker_threshold = float(v, "breaker_threshold")?;
        }
        if let Some(v) = t.get("breaker_cooldown_secs") {
            r.breaker_cooldown_secs = float(v, "breaker_cooldown_secs")?;
        }
        r.validate()?;
        Ok(r)
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_failover_retries == 0 {
            bail!("resilience max_failover_retries must be >= 1");
        }
        if !(self.direct_retry_backoff_secs > 0.0 && self.direct_retry_backoff_secs.is_finite()) {
            bail!(
                "resilience direct_retry_backoff_secs must be positive and finite, got {}",
                self.direct_retry_backoff_secs
            );
        }
        if !(self.deadline_factor == 0.0
            || (self.deadline_factor >= 1.0 && self.deadline_factor.is_finite()))
        {
            bail!(
                "resilience deadline_factor must be 0 (disabled) or >= 1, got {}",
                self.deadline_factor
            );
        }
        if !(self.breaker_alpha > 0.0 && self.breaker_alpha <= 1.0) {
            bail!(
                "resilience breaker_alpha must be in (0, 1], got {}",
                self.breaker_alpha
            );
        }
        if !(self.breaker_threshold > 0.0 && self.breaker_threshold < 1.0) {
            bail!(
                "resilience breaker_threshold must be in (0, 1), got {}",
                self.breaker_threshold
            );
        }
        if !(self.breaker_cooldown_secs > 0.0 && self.breaker_cooldown_secs.is_finite()) {
            bail!(
                "resilience breaker_cooldown_secs must be positive and finite, got {}",
                self.breaker_cooldown_secs
            );
        }
        Ok(())
    }

    /// Whether this config changes engine behavior relative to the
    /// defaults in a way that adds event sources or selection state —
    /// armed runs stay on the serial engine path (see the epoch gate
    /// in `federation::driver`).
    pub fn armed(&self) -> bool {
        self.deadline_factor > 0.0 || self.breaker
    }
}

/// A site: a geographic location hosting any combination of worker
/// nodes, a squid-like HTTP proxy, a StashCache cache, and origins.
#[derive(Debug, Clone)]
pub struct SiteConfig {
    pub name: String,
    pub lat: f64,
    pub lon: f64,
    /// Worker slots available for jobs (0 for pure cache PoPs).
    pub worker_slots: usize,
    /// Network characteristics.
    pub links: LinkProfile,
    /// Site HTTP forward proxy (every compute site has one on the OSG).
    pub proxy: Option<ProxyConfig>,
    /// StashCache cache, if this site hosts one (Figure 2 locations).
    pub cache: Option<CacheConfig>,
}

/// Per-site link bandwidths (Gbit/s) and latencies. The WAN core is
/// modelled as uncongested; contention happens at these edges, which is
/// how the paper explains its per-site differences (§5: "some sites
/// prioritize bandwidth to the HTTP proxy").
#[derive(Debug, Clone, Copy)]
pub struct LinkProfile {
    /// Site border ↔ WAN backbone.
    pub wan_gbps: f64,
    /// Worker ↔ site proxy (LAN).
    pub proxy_lan_gbps: f64,
    /// Site proxy ↔ border. Colorado provisions this much fatter than
    /// the worker path — the paper's outlier (§5, Table 3).
    pub proxy_wan_gbps: f64,
    /// Worker ↔ border (the path to a *remote* cache).
    pub worker_wan_gbps: f64,
    /// Worker ↔ local cache (LAN), when a cache exists on site.
    pub cache_lan_gbps: f64,
    /// Cache ↔ border (paper guarantees caches ≥ 10 Gbps).
    pub cache_wan_gbps: f64,
    /// Additional per-connection LAN round-trip (ms).
    pub lan_rtt_ms: f64,
}

/// StashCache cache service parameters (XRootD caching proxy).
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    /// Total cache space ("several TBs" — paper §1).
    pub capacity: ByteSize,
    /// Eviction high watermark as a fraction of capacity (start evicting).
    pub high_watermark: f64,
    /// Eviction low watermark (evict down to this).
    pub low_watermark: f64,
    /// Chunk size for partial-file caching (CVMFS uses 24 MB — §3.1).
    pub chunk_size: ByteSize,
    /// Per-connection delivery ceiling (Gbit/s). XRootD caches use
    /// multi-threaded, multi-stream transfers (paper §3.1), so this is
    /// high — the effective rate is normally link-limited instead.
    pub per_conn_gbps: f64,
}

/// Squid-like HTTP forward proxy parameters (the paper's baseline).
#[derive(Debug, Clone, Copy)]
pub struct ProxyConfig {
    /// Object store capacity.
    pub capacity: ByteSize,
    /// Largest object the proxy will cache. The paper observed site
    /// proxies never cached the 2.335 GB and 10 GB files (§5).
    pub max_object: ByteSize,
    /// Time-to-live before a cached object expires. The paper hit
    /// rapid expiry during its test loop (§5).
    pub ttl_secs: f64,
    /// Per-connection delivery ceiling (Gbit/s). Squid-style proxies
    /// are "optimized for small files" (paper §1): a single HTTP
    /// stream through the proxy tops out well below the NIC rate.
    pub per_conn_gbps: f64,
}

/// Origin server registration.
#[derive(Debug, Clone)]
pub struct OriginConfig {
    pub name: String,
    /// Site hosting the origin (must exist in `sites`).
    pub site: String,
    /// Namespace prefix this origin is authoritative for, e.g.
    /// `/ospool/ligo`.
    pub prefix: String,
}

/// Client tool used for a download (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClientKind {
    /// `stashcp` → cvmfs → xrootd → curl fallback chain.
    Stashcp,
    /// CVMFS POSIX chunked reader.
    Cvmfs,
    /// Plain HTTP through the site proxy.
    CurlProxy,
}

/// Workload description for the long-running usage simulations
/// (Table 1, Table 2, Figure 4, Figure 5).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Experiments and their relative usage share (Table 1 ratios).
    pub experiments: Vec<ExperimentMix>,
    /// Zipf exponent for file popularity within an experiment.
    pub zipf_s: f64,
    /// Catalog size (distinct files) per experiment.
    pub files_per_experiment: u64,
    /// Log-normal mixture for file sizes, fitted to Table 2.
    pub size_dist: SizeDistribution,
    /// Mean job arrival rate across the federation (jobs/hour).
    pub jobs_per_hour: f64,
    /// Files read per job (uniform range).
    pub files_per_job: (u64, u64),
}

/// One experiment's share of the workload.
#[derive(Debug, Clone)]
pub struct ExperimentMix {
    pub name: String,
    /// Relative weight (normalised internally).
    pub share: f64,
}

/// Mixture of log-normal components for file sizes. Calibrated in
/// `defaults::paper_size_distribution` to hit the Table 2 percentiles.
#[derive(Debug, Clone)]
pub struct SizeDistribution {
    /// (weight, mu, sigma) of ln(bytes).
    pub components: Vec<(f64, f64, f64)>,
    /// Hard clamp (largest file the paper tested was 10 GB).
    pub min: ByteSize,
    pub max: ByteSize,
}

impl FederationConfig {
    /// Load from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let table = toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        Self::from_table(&table)
    }

    /// Load from a TOML file path.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
    }

    fn from_table(t: &Table) -> Result<Self> {
        let fed = t
            .get("federation")
            .and_then(Value::as_table)
            .ok_or_else(|| anyhow!("missing [federation] table"))?;
        let name = get_str(fed, "name").unwrap_or_else(|_| "stashcache".into());
        let seed = get_int(fed, "seed") as u64;
        let redirector_instances = fed
            .get("redirector_instances")
            .and_then(Value::as_int)
            .unwrap_or(2) as usize;
        let redirection = match t.get("redirection") {
            None => RedirectionConfig::default(),
            Some(v) => {
                let rt = v
                    .as_table()
                    .ok_or_else(|| anyhow!("[redirection] must be a table"))?;
                // No context wrap: the shim's Display shows only the
                // outermost layer, and every message below already
                // names the [redirection] table.
                RedirectionConfig::from_table(rt)?
            }
        };
        let resilience = match t.get("resilience") {
            None => ResilienceConfig::default(),
            Some(v) => {
                let rt = v
                    .as_table()
                    .ok_or_else(|| anyhow!("[resilience] must be a table"))?;
                ResilienceConfig::from_table(rt)?
            }
        };

        let mut sites = Vec::new();
        if let Some(arr) = t.get("site").and_then(Value::as_array) {
            for (i, v) in arr.iter().enumerate() {
                let st = v
                    .as_table()
                    .ok_or_else(|| anyhow!("[[site]] #{i} is not a table"))?;
                sites.push(SiteConfig::from_table(st).with_context(|| format!("site #{i}"))?);
            }
        }

        let mut origins = Vec::new();
        if let Some(arr) = t.get("origin").and_then(Value::as_array) {
            for (i, v) in arr.iter().enumerate() {
                let ot = v
                    .as_table()
                    .ok_or_else(|| anyhow!("[[origin]] #{i} is not a table"))?;
                origins.push(OriginConfig {
                    name: get_str(ot, "name")?,
                    site: get_str(ot, "site")?,
                    prefix: get_str(ot, "prefix")?,
                });
            }
        }

        let workload = match t.get("workload").and_then(Value::as_table) {
            Some(wt) => WorkloadConfig::from_table(wt)?,
            None => super::defaults::paper_workload(),
        };

        let cfg = FederationConfig {
            name,
            seed,
            redirector_instances,
            redirection,
            resilience,
            sites,
            origins,
            workload,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural validation (referential integrity + numeric sanity).
    pub fn validate(&self) -> Result<()> {
        if self.sites.is_empty() {
            bail!("no sites configured");
        }
        if self.redirector_instances == 0 {
            bail!("redirector_instances must be >= 1");
        }
        self.redirection.validate()?;
        self.resilience.validate()?;
        let mut names = std::collections::HashSet::new();
        for s in &self.sites {
            if !names.insert(s.name.as_str()) {
                bail!("duplicate site name {:?}", s.name);
            }
            s.validate()?;
        }
        if self.origins.is_empty() {
            bail!("no origins configured");
        }
        let mut prefixes = std::collections::HashSet::new();
        for o in &self.origins {
            if !names.contains(o.site.as_str()) {
                bail!("origin {:?} references unknown site {:?}", o.name, o.site);
            }
            if !o.prefix.starts_with('/') {
                bail!("origin prefix {:?} must start with '/'", o.prefix);
            }
            if !prefixes.insert(o.prefix.as_str()) {
                bail!("duplicate origin prefix {:?}", o.prefix);
            }
        }
        if !self.sites.iter().any(|s| s.cache.is_some()) {
            bail!("no cache sites configured");
        }
        self.workload.validate()?;
        Ok(())
    }

    /// Sites hosting a cache (Figure 2 locations).
    pub fn cache_sites(&self) -> impl Iterator<Item = &SiteConfig> {
        self.sites.iter().filter(|s| s.cache.is_some())
    }

    /// Sites with worker slots (compute sites).
    pub fn compute_sites(&self) -> impl Iterator<Item = &SiteConfig> {
        self.sites.iter().filter(|s| s.worker_slots > 0)
    }

    pub fn site(&self, name: &str) -> Option<&SiteConfig> {
        self.sites.iter().find(|s| s.name == name)
    }
}

impl SiteConfig {
    fn from_table(t: &Table) -> Result<Self> {
        let links = match t.get("links").and_then(Value::as_table) {
            Some(lt) => LinkProfile::from_table(lt)?,
            None => LinkProfile::default(),
        };
        let proxy = match t.get("proxy").and_then(Value::as_table) {
            Some(pt) => Some(ProxyConfig::from_table(pt)?),
            None => None,
        };
        let cache = match t.get("cache").and_then(Value::as_table) {
            Some(ct) => Some(CacheConfig::from_table(ct)?),
            None => None,
        };
        Ok(SiteConfig {
            name: get_str(t, "name")?,
            lat: get_float(t, "lat")?,
            lon: get_float(t, "lon")?,
            worker_slots: t
                .get("worker_slots")
                .and_then(Value::as_int)
                .unwrap_or(0) as usize,
            links,
            proxy,
            cache,
        })
    }

    fn validate(&self) -> Result<()> {
        if !(-90.0..=90.0).contains(&self.lat) || !(-180.0..=180.0).contains(&self.lon) {
            bail!("site {:?} has invalid coordinates", self.name);
        }
        let l = &self.links;
        for (label, v) in [
            ("wan_gbps", l.wan_gbps),
            ("proxy_lan_gbps", l.proxy_lan_gbps),
            ("proxy_wan_gbps", l.proxy_wan_gbps),
            ("worker_wan_gbps", l.worker_wan_gbps),
            ("cache_lan_gbps", l.cache_lan_gbps),
            ("cache_wan_gbps", l.cache_wan_gbps),
        ] {
            if !(v > 0.0 && v.is_finite()) {
                bail!("site {:?}: {label} must be positive, got {v}", self.name);
            }
        }
        if let Some(c) = &self.cache {
            if !(0.0 < c.low_watermark && c.low_watermark < c.high_watermark
                && c.high_watermark <= 1.0)
            {
                bail!(
                    "site {:?}: watermarks must satisfy 0 < low < high <= 1",
                    self.name
                );
            }
            if c.chunk_size.0 == 0 || c.capacity.0 < c.chunk_size.0 {
                bail!("site {:?}: cache capacity < chunk size", self.name);
            }
            if c.per_conn_gbps <= 0.0 {
                bail!("site {:?}: cache per_conn_gbps must be > 0", self.name);
            }
        }
        if let Some(p) = &self.proxy {
            if p.capacity.0 == 0 {
                bail!("site {:?}: proxy capacity must be > 0", self.name);
            }
            if p.ttl_secs <= 0.0 {
                bail!("site {:?}: proxy ttl must be > 0", self.name);
            }
            if p.per_conn_gbps <= 0.0 {
                bail!("site {:?}: proxy per_conn_gbps must be > 0", self.name);
            }
        }
        if self.worker_slots > 0 && self.proxy.is_none() {
            bail!(
                "compute site {:?} needs a proxy (every OSG compute site has one)",
                self.name
            );
        }
        Ok(())
    }
}

impl LinkProfile {
    fn from_table(t: &Table) -> Result<Self> {
        let d = LinkProfile::default();
        Ok(LinkProfile {
            wan_gbps: opt_float(t, "wan_gbps")?.unwrap_or(d.wan_gbps),
            proxy_lan_gbps: opt_float(t, "proxy_lan_gbps")?.unwrap_or(d.proxy_lan_gbps),
            proxy_wan_gbps: opt_float(t, "proxy_wan_gbps")?.unwrap_or(d.proxy_wan_gbps),
            worker_wan_gbps: opt_float(t, "worker_wan_gbps")?.unwrap_or(d.worker_wan_gbps),
            cache_lan_gbps: opt_float(t, "cache_lan_gbps")?.unwrap_or(d.cache_lan_gbps),
            cache_wan_gbps: opt_float(t, "cache_wan_gbps")?.unwrap_or(d.cache_wan_gbps),
            lan_rtt_ms: opt_float(t, "lan_rtt_ms")?.unwrap_or(d.lan_rtt_ms),
        })
    }
}

impl Default for LinkProfile {
    fn default() -> Self {
        LinkProfile {
            wan_gbps: 10.0,
            proxy_lan_gbps: 10.0,
            proxy_wan_gbps: 10.0,
            worker_wan_gbps: 5.0,
            cache_lan_gbps: 10.0,
            cache_wan_gbps: 10.0,
            lan_rtt_ms: 0.3,
        }
    }
}

impl CacheConfig {
    fn from_table(t: &Table) -> Result<Self> {
        let d = CacheConfig::default();
        Ok(CacheConfig {
            capacity: opt_bytes(t, "capacity")?.unwrap_or(d.capacity),
            high_watermark: opt_float(t, "high_watermark")?.unwrap_or(d.high_watermark),
            low_watermark: opt_float(t, "low_watermark")?.unwrap_or(d.low_watermark),
            chunk_size: opt_bytes(t, "chunk_size")?.unwrap_or(d.chunk_size),
            per_conn_gbps: opt_float(t, "per_conn_gbps")?.unwrap_or(d.per_conn_gbps),
        })
    }
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity: ByteSize::tb(8),
            high_watermark: 0.95,
            low_watermark: 0.85,
            chunk_size: ByteSize::mb(24),
            per_conn_gbps: 8.0,
        }
    }
}

impl ProxyConfig {
    fn from_table(t: &Table) -> Result<Self> {
        let d = ProxyConfig::default();
        Ok(ProxyConfig {
            capacity: opt_bytes(t, "capacity")?.unwrap_or(d.capacity),
            max_object: opt_bytes(t, "max_object")?.unwrap_or(d.max_object),
            ttl_secs: opt_float(t, "ttl_secs")?.unwrap_or(d.ttl_secs),
            per_conn_gbps: opt_float(t, "per_conn_gbps")?.unwrap_or(d.per_conn_gbps),
        })
    }
}

impl Default for ProxyConfig {
    fn default() -> Self {
        ProxyConfig {
            // Typical OSG squid: tens of GB of disk, 512 MB-1 GB max
            // object, aggressive expiry tuned for software/conditions
            // data (paper §1 and §5).
            capacity: ByteSize::gb(100),
            max_object: ByteSize::gb(1),
            ttl_secs: 3_600.0,
            per_conn_gbps: 1.2,
        }
    }
}

impl WorkloadConfig {
    fn from_table(t: &Table) -> Result<Self> {
        let mut w = super::defaults::paper_workload();
        if let Some(v) = opt_float(t, "zipf_s")? {
            w.zipf_s = v;
        }
        if let Some(v) = t.get("files_per_experiment").and_then(Value::as_int) {
            w.files_per_experiment = v as u64;
        }
        if let Some(v) = opt_float(t, "jobs_per_hour")? {
            w.jobs_per_hour = v;
        }
        if let Some(arr) = t.get("experiments").and_then(Value::as_array) {
            w.experiments.clear();
            for v in arr {
                let et = v.as_table().ok_or_else(|| anyhow!("experiment not a table"))?;
                w.experiments.push(ExperimentMix {
                    name: get_str(et, "name")?,
                    share: get_float(et, "share")?,
                });
            }
        }
        w.validate()?;
        Ok(w)
    }

    pub fn validate(&self) -> Result<()> {
        if self.experiments.is_empty() {
            bail!("workload has no experiments");
        }
        if self.experiments.iter().any(|e| e.share <= 0.0) {
            bail!("experiment shares must be positive");
        }
        if self.zipf_s < 0.0 || self.files_per_experiment == 0 {
            bail!("invalid popularity parameters");
        }
        if self.jobs_per_hour <= 0.0 {
            bail!("jobs_per_hour must be positive");
        }
        if self.files_per_job.0 == 0 || self.files_per_job.0 > self.files_per_job.1 {
            bail!("files_per_job range invalid");
        }
        let (total, _, _) = self
            .size_dist
            .components
            .iter()
            .fold((0.0, 0.0, 0.0), |acc, c| (acc.0 + c.0, c.1, c.2));
        if (total - 1.0).abs() > 1e-6 {
            bail!("size distribution weights must sum to 1, got {total}");
        }
        Ok(())
    }
}

// --- small typed accessors -------------------------------------------------

fn get_str(t: &Table, key: &str) -> Result<String> {
    t.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing string key {key:?}"))
}

fn get_int(t: &Table, key: &str) -> i64 {
    t.get(key).and_then(Value::as_int).unwrap_or(42)
}

fn get_float(t: &Table, key: &str) -> Result<f64> {
    t.get(key)
        .and_then(Value::as_float)
        .ok_or_else(|| anyhow!("missing numeric key {key:?}"))
}

fn opt_float(t: &Table, key: &str) -> Result<Option<f64>> {
    match t.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_float()
            .map(Some)
            .ok_or_else(|| anyhow!("key {key:?} is not numeric")),
    }
}

fn opt_bytes(t: &Table, key: &str) -> Result<Option<ByteSize>> {
    match t.get(key) {
        None => Ok(None),
        Some(Value::Str(s)) => Ok(Some(
            s.parse::<ByteSize>().map_err(|e| anyhow!("{key}: {e}"))?,
        )),
        Some(Value::Int(i)) if *i >= 0 => Ok(Some(ByteSize(*i as u64))),
        Some(v) => bail!("key {key:?} is not a byte size: {v}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults;

    #[test]
    fn default_config_validates() {
        let cfg = defaults::paper_federation();
        cfg.validate().unwrap();
        assert_eq!(cfg.compute_sites().count(), 5);
        assert_eq!(cfg.cache_sites().count(), 10);
    }

    #[test]
    fn parse_minimal_toml() {
        let cfg = FederationConfig::from_toml(
            r#"
            [federation]
            name = "mini"
            seed = 7

            [[site]]
            name = "a"
            lat = 40.0
            lon = -100.0
            worker_slots = 4
            [site.links]
            wan_gbps = 10.0
            [site.proxy]
            capacity = "50GB"
            max_object = "1GB"
            ttl_secs = 600.0
            [site.cache]
            capacity = "2TB"

            [[origin]]
            name = "o1"
            site = "a"
            prefix = "/data"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "mini");
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.sites.len(), 1);
        let s = &cfg.sites[0];
        assert_eq!(s.proxy.unwrap().capacity, ByteSize::gb(50));
        assert_eq!(s.cache.unwrap().capacity, ByteSize::tb(2));
        // defaults fill in unspecified knobs
        assert_eq!(s.cache.unwrap().chunk_size, ByteSize::mb(24));
    }

    #[test]
    fn parse_redirection_table() {
        let cfg = FederationConfig::from_toml(
            r#"
            [federation]
            name = "mini"
            seed = 7

            [redirection]
            policy = "consistent-hash"
            virtual_nodes = 8

            [[site]]
            name = "a"
            lat = 40.0
            lon = -100.0
            [site.cache]
            capacity = "2TB"

            [[origin]]
            name = "o1"
            site = "a"
            prefix = "/data"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.redirection.policy, PolicyKind::ConsistentHash);
        assert_eq!(cfg.redirection.virtual_nodes, 8);
        // Unspecified knobs inherit the defaults.
        let d = RedirectionConfig::default();
        assert_eq!(cfg.redirection.nearest_k, d.nearest_k);
        assert_eq!(cfg.redirection.location_cache_cap, d.location_cache_cap);
    }

    #[test]
    fn redirection_defaults_to_nearest_without_table() {
        let cfg = defaults::paper_federation();
        assert_eq!(cfg.redirection.policy, PolicyKind::Nearest);
        assert_eq!(cfg.redirection, RedirectionConfig::default());
    }

    #[test]
    fn redirection_table_is_strict() {
        let parse = |body: &str| {
            FederationConfig::from_toml(&format!(
                "[federation]\nname = \"x\"\nseed = 1\n\n[redirection]\n{body}\n\n\
                 [[site]]\nname = \"a\"\nlat = 0.0\nlon = 0.0\n[site.cache]\ncapacity = \"1TB\"\n\n\
                 [[origin]]\nname = \"o\"\nsite = \"a\"\nprefix = \"/d\"\n"
            ))
        };
        let e = parse("polcy = \"nearest\"").unwrap_err();
        assert!(e.to_string().contains("unknown key"), "{e}");
        assert!(parse("policy = \"random\"").is_err());
        assert!(parse("policy = 3").is_err());
        assert!(parse("nearest_k = 0").is_err());
        assert!(parse("virtual_nodes = -4").is_err());
        assert!(parse("regional_km = 0.0").is_err());
        assert!(parse("location_cache_cap = 0").is_err());
        assert!(parse("policy = \"tiered\"\nregional_km = 500.0").is_ok());
    }

    #[test]
    fn resilience_defaults_match_todays_consts() {
        let cfg = defaults::paper_federation();
        assert_eq!(
            cfg.resilience.max_failover_retries,
            crate::fault::MAX_FAILOVER_RETRIES
        );
        assert_eq!(
            cfg.resilience.direct_retry_backoff_secs,
            crate::fault::DIRECT_RETRY_BACKOFF.as_secs_f64()
        );
        assert_eq!(cfg.resilience.deadline_factor, 0.0);
        assert!(!cfg.resilience.breaker);
        assert!(!cfg.resilience.armed(), "defaults arm nothing");
        assert_eq!(cfg.resilience, ResilienceConfig::default());
    }

    #[test]
    fn parse_resilience_table() {
        let cfg = FederationConfig::from_toml(
            r#"
            [federation]
            name = "mini"
            seed = 7

            [resilience]
            deadline_factor = 4.0
            breaker = true
            breaker_cooldown_secs = 12.5

            [[site]]
            name = "a"
            lat = 40.0
            lon = -100.0
            [site.cache]
            capacity = "2TB"

            [[origin]]
            name = "o1"
            site = "a"
            prefix = "/data"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.resilience.deadline_factor, 4.0);
        assert!(cfg.resilience.breaker);
        assert_eq!(cfg.resilience.breaker_cooldown_secs, 12.5);
        assert!(cfg.resilience.armed());
        // Unspecified knobs inherit the defaults.
        let d = ResilienceConfig::default();
        assert_eq!(cfg.resilience.max_failover_retries, d.max_failover_retries);
        assert_eq!(cfg.resilience.breaker_alpha, d.breaker_alpha);
    }

    #[test]
    fn resilience_table_is_strict() {
        let parse = |body: &str| {
            FederationConfig::from_toml(&format!(
                "[federation]\nname = \"x\"\nseed = 1\n\n[resilience]\n{body}\n\n\
                 [[site]]\nname = \"a\"\nlat = 0.0\nlon = 0.0\n[site.cache]\ncapacity = \"1TB\"\n\n\
                 [[origin]]\nname = \"o\"\nsite = \"a\"\nprefix = \"/d\"\n"
            ))
        };
        let e = parse("max_failover_retrys = 3").unwrap_err();
        assert!(e.to_string().contains("unknown key"), "{e}");
        assert!(parse("max_failover_retries = 0").is_err());
        assert!(parse("max_failover_retries = \"three\"").is_err());
        assert!(parse("direct_retry_backoff_secs = 0.0").is_err());
        assert!(parse("deadline_factor = 0.5").is_err(), "sub-1 factors reject");
        assert!(parse("deadline_factor = -2.0").is_err());
        assert!(parse("breaker = \"yes\"").is_err());
        assert!(parse("breaker_alpha = 0.0").is_err());
        assert!(parse("breaker_alpha = 1.5").is_err());
        assert!(parse("breaker_threshold = 1.0").is_err());
        assert!(parse("breaker_cooldown_secs = -1.0").is_err());
        assert!(parse("deadline_factor = 3.0\nbreaker = true").is_ok());
        assert!(parse("deadline_factor = 0.0").is_ok(), "0 = disabled is valid");
    }

    #[test]
    fn rejects_unknown_origin_site() {
        let e = FederationConfig::from_toml(
            r#"
            [federation]
            name = "x"
            [[site]]
            name = "a"
            lat = 0.0
            lon = 0.0
            [site.cache]
            capacity = "1TB"
            [[origin]]
            name = "o"
            site = "nowhere"
            prefix = "/d"
            "#,
        )
        .unwrap_err();
        assert!(e.to_string().contains("unknown site"));
    }

    #[test]
    fn rejects_bad_watermarks() {
        let mut cfg = defaults::paper_federation();
        for s in &mut cfg.sites {
            if let Some(c) = &mut s.cache {
                c.low_watermark = 0.99;
                c.high_watermark = 0.5;
            }
        }
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_compute_site_without_proxy() {
        let mut cfg = defaults::paper_federation();
        let s = cfg
            .sites
            .iter_mut()
            .find(|s| s.worker_slots > 0)
            .unwrap();
        s.proxy = None;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_duplicate_prefix() {
        let mut cfg = defaults::paper_federation();
        let dup = cfg.origins[0].clone();
        cfg.origins.push(dup);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn workload_share_validation() {
        let mut w = defaults::paper_workload();
        w.experiments[0].share = -1.0;
        assert!(w.validate().is_err());
    }
}
