//! Configuration system.
//!
//! Federation topology (sites, caches, proxies, origins, link
//! bandwidths), workload mixes and experiment parameters are described
//! in a TOML file. The offline crate set has no `serde`/`toml`, so
//! [`toml`] is a from-scratch parser for the subset we use, [`schema`]
//! maps the parsed tree onto typed structs with validation, and
//! [`defaults`] embeds the calibrated topology of the paper's testbed
//! (the five OSG sites of §4.1 plus the cache deployment of Figure 2).

pub mod defaults;
pub mod schema;
pub mod toml;

pub use schema::{
    CacheConfig, ClientKind, FederationConfig, LinkProfile, OriginConfig, ProxyConfig,
    RedirectionConfig, ResilienceConfig, SiteConfig, WorkloadConfig,
};
