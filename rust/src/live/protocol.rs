//! Wire protocol for live-mode TCP services.
//!
//! XRootD's wire format is not the paper's contribution, so live mode
//! speaks a minimal length-prefixed binary protocol with the same
//! roles: stat, read, locate. Frames:
//!
//! ```text
//! frame:    len u32 | kind u8 | body...
//! Stat:     pathlen u16 | path
//! Read:     offset u64 | len u64 | pathlen u16 | path
//! Locate:   pathlen u16 | path
//! StatOk:   size u64 | mtime u64
//! Data:     payload...            (exactly the requested bytes)
//! Located:  addrlen u16 | addr    (host:port of the origin)
//! Error:    msglen u16 | msg
//! ```

use byteorder::{BigEndian, ReadBytesExt, WriteBytesExt};
use std::io::{Read as _, Write as _};
use std::net::TcpStream;

/// Maximum frame size (64 MiB — bigger than any chunk we move).
pub const MAX_FRAME: u32 = 64 << 20;

/// A protocol message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Msg {
    Stat { path: String },
    Read { offset: u64, len: u64, path: String },
    Locate { path: String },
    StatOk { size: u64, mtime: u64 },
    Data(Vec<u8>),
    Located { addr: String },
    Error(String),
}

const K_STAT: u8 = 1;
const K_READ: u8 = 2;
const K_LOCATE: u8 = 3;
const K_STATOK: u8 = 4;
const K_DATA: u8 = 5;
const K_LOCATED: u8 = 6;
const K_ERROR: u8 = 7;

/// Send one frame.
pub fn send(stream: &mut TcpStream, msg: &Msg) -> std::io::Result<()> {
    let mut body = Vec::new();
    match msg {
        Msg::Stat { path } => {
            body.write_u8(K_STAT)?;
            put_str(&mut body, path)?;
        }
        Msg::Read { offset, len, path } => {
            body.write_u8(K_READ)?;
            body.write_u64::<BigEndian>(*offset)?;
            body.write_u64::<BigEndian>(*len)?;
            put_str(&mut body, path)?;
        }
        Msg::Locate { path } => {
            body.write_u8(K_LOCATE)?;
            put_str(&mut body, path)?;
        }
        Msg::StatOk { size, mtime } => {
            body.write_u8(K_STATOK)?;
            body.write_u64::<BigEndian>(*size)?;
            body.write_u64::<BigEndian>(*mtime)?;
        }
        Msg::Data(payload) => {
            body.write_u8(K_DATA)?;
            body.extend_from_slice(payload);
        }
        Msg::Located { addr } => {
            body.write_u8(K_LOCATED)?;
            put_str(&mut body, addr)?;
        }
        Msg::Error(e) => {
            body.write_u8(K_ERROR)?;
            put_str(&mut body, e)?;
        }
    }
    stream.write_u32::<BigEndian>(body.len() as u32)?;
    stream.write_all(&body)?;
    stream.flush()
}

/// Receive one frame.
pub fn recv(stream: &mut TcpStream) -> std::io::Result<Msg> {
    let len = stream.read_u32::<BigEndian>()?;
    if len == 0 || len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    stream.read_exact(&mut body)?;
    let mut cur = std::io::Cursor::new(&body[..]);
    let kind = cur.read_u8()?;
    let msg = match kind {
        K_STAT => Msg::Stat { path: get_str(&mut cur)? },
        K_READ => {
            let offset = cur.read_u64::<BigEndian>()?;
            let len = cur.read_u64::<BigEndian>()?;
            Msg::Read { offset, len, path: get_str(&mut cur)? }
        }
        K_LOCATE => Msg::Locate { path: get_str(&mut cur)? },
        K_STATOK => Msg::StatOk {
            size: cur.read_u64::<BigEndian>()?,
            mtime: cur.read_u64::<BigEndian>()?,
        },
        K_DATA => {
            let pos = cur.position() as usize;
            Msg::Data(body[pos..].to_vec())
        }
        K_LOCATED => Msg::Located { addr: get_str(&mut cur)? },
        K_ERROR => Msg::Error(get_str(&mut cur)?),
        other => {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("unknown message kind {other}"),
            ))
        }
    };
    Ok(msg)
}

fn put_str(buf: &mut Vec<u8>, s: &str) -> std::io::Result<()> {
    buf.write_u16::<BigEndian>(s.len().min(u16::MAX as usize) as u16)?;
    buf.extend_from_slice(&s.as_bytes()[..s.len().min(u16::MAX as usize)]);
    Ok(())
}

fn get_str(cur: &mut std::io::Cursor<&[u8]>) -> std::io::Result<String> {
    let len = cur.read_u16::<BigEndian>()? as usize;
    let mut bytes = vec![0u8; len];
    cur.read_exact(&mut bytes)?;
    String::from_utf8(bytes)
        .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad utf8"))
}

/// Round-trip a request over a fresh connection.
pub fn request(addr: &str, msg: &Msg) -> std::io::Result<Msg> {
    let mut stream = TcpStream::connect(addr)?;
    send(&mut stream, msg)?;
    recv(&mut stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn frames_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            for _ in 0..5 {
                let m = recv(&mut s).unwrap();
                send(&mut s, &m).unwrap();
            }
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let msgs = [
            Msg::Stat { path: "/ospool/ligo/f".into() },
            Msg::Read { offset: 7, len: 42, path: "/p".into() },
            Msg::Locate { path: "/x".into() },
            Msg::Data(vec![1, 2, 3, 255]),
            Msg::Error("nope".into()),
        ];
        for m in &msgs {
            send(&mut c, m).unwrap();
            assert_eq!(&recv(&mut c).unwrap(), m);
        }
        echo.join().unwrap();
    }

    #[test]
    fn oversized_frame_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            recv(&mut s)
        });
        let mut c = TcpStream::connect(addr).unwrap();
        use byteorder::WriteBytesExt;
        c.write_u32::<BigEndian>(MAX_FRAME + 1).unwrap();
        use std::io::Write;
        c.flush().unwrap();
        assert!(t.join().unwrap().is_err());
    }
}
