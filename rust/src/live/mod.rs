//! Live mode: the federation as real TCP/UDP processes.
//!
//! The simulator (DESIGN.md §2 row 1) answers the paper's *performance*
//! questions; this module proves the protocol stack is real. The same
//! service state machines (origin, redirector, cache, monitoring
//! collector) run behind actual sockets on loopback:
//!
//! * origins serve [`crate::origin::content`] bytes over a
//!   length-prefixed TCP protocol ([`protocol`]);
//! * the redirector answers location queries by querying origins;
//! * caches capture client requests, fetch misses from the located
//!   origin, store real bytes, and emit **real UDP monitoring
//!   packets** (the §3.2 format) to the collector daemon;
//! * `stashcp_live` picks the nearest cache by GeoIP, downloads, and
//!   verifies content checksums.
//!
//! The offline crate set has no tokio (DESIGN.md §2 row 16), so
//! concurrency is thread-per-connection over `std::net` — the same
//! architecture XRootD itself uses.

pub mod client;
pub mod protocol;
pub mod server;

pub use client::stashcp_live;
pub use server::{CollectorDaemon, LiveCache, LiveOrigin, LiveRedirector};
