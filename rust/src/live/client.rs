//! Live-mode client: `stashcp` against real sockets.
//!
//! Implements the §3.1 client behaviour end-to-end: pick the nearest
//! cache with the GeoIP service, stat the file through the cache,
//! download it (whole-file, like stashcp), and verify the payload
//! against the content keystream — the integrity check CVMFS's
//! catalog checksums provide in production.

use super::protocol::{self, Msg};
use crate::geoip::{CacheSite, NearestCache, RustGeoBackend};
use crate::origin::content;

/// A cache endpoint in the live federation: geo position + address.
#[derive(Debug, Clone)]
pub struct LiveCacheEndpoint {
    pub site: CacheSite,
    pub addr: String,
}

/// Result of a live download.
#[derive(Debug)]
pub struct LiveTransfer {
    pub bytes: Vec<u8>,
    pub cache_used: String,
    pub verified: bool,
    pub wall: std::time::Duration,
}

/// Download `path` from the nearest cache to `(lat, lon)`.
///
/// Mirrors stashcp: GeoIP ranking first, then tries caches in order
/// until one answers (the fallback the paper's client implements with
/// its three methods).
pub fn stashcp_live(
    path: &str,
    lat: f64,
    lon: f64,
    caches: &[LiveCacheEndpoint],
) -> Result<LiveTransfer, String> {
    assert!(!caches.is_empty(), "no caches in federation");
    let start = std::time::Instant::now();
    let sites: Vec<CacheSite> = caches.iter().map(|c| c.site.clone()).collect();
    let mut geo = NearestCache::with_backend(sites, RustGeoBackend);
    let loads = vec![0.0; caches.len()];
    let ranked = geo.rank(lat, lon, &loads);

    let mut last_err = String::new();
    for (idx, _) in ranked {
        let endpoint = &caches[idx];
        match try_download(path, &endpoint.addr) {
            Ok((bytes, mtime)) => {
                let verified = content::verify(path, mtime, 0, &bytes);
                return Ok(LiveTransfer {
                    bytes,
                    cache_used: endpoint.site.name.clone(),
                    verified,
                    wall: start.elapsed(),
                });
            }
            Err(e) => last_err = format!("{}: {e}", endpoint.site.name),
        }
    }
    Err(format!("all caches failed; last error: {last_err}"))
}

fn try_download(path: &str, addr: &str) -> Result<(Vec<u8>, u64), String> {
    let (size, mtime) = match protocol::request(addr, &Msg::Stat { path: path.into() }) {
        Ok(Msg::StatOk { size, mtime }) => (size, mtime),
        Ok(Msg::Error(e)) => return Err(e),
        Ok(other) => return Err(format!("bad stat reply {other:?}")),
        Err(e) => return Err(e.to_string()),
    };
    match protocol::request(addr, &Msg::Read { offset: 0, len: size, path: path.into() }) {
        Ok(Msg::Data(bytes)) if bytes.len() as u64 == size => Ok((bytes, mtime)),
        Ok(Msg::Data(bytes)) => Err(format!("short read: {} of {size}", bytes.len())),
        Ok(Msg::Error(e)) => Err(e),
        Ok(other) => Err(format!("bad read reply {other:?}")),
        Err(e) => Err(e.to_string()),
    }
}
