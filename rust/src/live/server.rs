//! Live-mode services: origin, redirector, cache, monitoring collector.
//!
//! Thread-per-connection over `std::net`. Each service owns a
//! listener thread; `stop()` flips an atomic and nudges the listener
//! awake. State shared with handler threads sits behind mutexes —
//! coarse, but the request path does one lock per frame.

use super::protocol::{self, Msg};
use crate::cache::CacheServer;
use crate::config::CacheConfig;
use crate::monitoring::aggregator::Aggregator;
use crate::monitoring::bus::Bus;
use crate::monitoring::collector::{Collector, TRANSFER_TOPIC};
use crate::monitoring::packets::{self, Envelope, Packet, Protocol};
use crate::namespace::{Namespace, OriginId};
use crate::origin::{content, FileMeta, Origin};
use crate::util::SimTime;
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

fn spawn_listener(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    handler: impl Fn(TcpStream) + Send + Sync + 'static,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let handler = Arc::new(handler);
        for conn in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let h = Arc::clone(&handler);
                    std::thread::spawn(move || h(stream));
                }
                Err(_) => break,
            }
        }
    })
}

fn stop_listener(addr: &str, stop: &AtomicBool) {
    stop.store(true, Ordering::SeqCst);
    // Nudge accept() awake.
    let _ = TcpStream::connect(addr);
}

/// A live origin server exporting one prefix with synthetic content.
pub struct LiveOrigin {
    pub addr: String,
    state: Arc<Mutex<Origin>>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl LiveOrigin {
    pub fn start(name: &str, prefix: &str, files: &[(&str, u64, u64)]) -> std::io::Result<Self> {
        let mut origin = Origin::new(OriginId(0), name, prefix);
        for &(path, size, mtime) in files {
            origin
                .put_file(path, FileMeta { size, mtime, perm: 0o644 })
                .expect("file under prefix");
        }
        let state = Arc::new(Mutex::new(origin));
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let st = Arc::clone(&state);
        let handle = spawn_listener(listener, Arc::clone(&stop), move |mut stream| {
            while let Ok(msg) = protocol::recv(&mut stream) {
                let reply = match msg {
                    Msg::Stat { path } => match st.lock().unwrap().stat(&path) {
                        Ok(meta) => Msg::StatOk { size: meta.size, mtime: meta.mtime },
                        Err(e) => Msg::Error(e.to_string()),
                    },
                    Msg::Read { offset, len, path } => {
                        let meta = { st.lock().unwrap().read(&path, offset, len) };
                        match meta {
                            Ok(meta) => {
                                let mut buf = vec![0u8; len as usize];
                                content::fill(&path, meta.mtime, offset, &mut buf);
                                Msg::Data(buf)
                            }
                            Err(e) => Msg::Error(e.to_string()),
                        }
                    }
                    Msg::Locate { path } => {
                        if st.lock().unwrap().locate(&path) {
                            Msg::StatOk { size: 0, mtime: 0 }
                        } else {
                            Msg::Error("not here".into())
                        }
                    }
                    other => Msg::Error(format!("unexpected {other:?}")),
                };
                if protocol::send(&mut stream, &reply).is_err() {
                    break;
                }
            }
        });
        Ok(LiveOrigin {
            addr,
            state,
            stop,
            handle: Some(handle),
        })
    }

    pub fn bytes_served(&self) -> u64 {
        self.state.lock().unwrap().bytes_served
    }
}

impl Drop for LiveOrigin {
    fn drop(&mut self) {
        stop_listener(&self.addr, &self.stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A live redirector: knows origin addresses + prefixes, answers
/// Locate by namespace then confirms with the origin itself.
pub struct LiveRedirector {
    pub addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl LiveRedirector {
    pub fn start(origins: Vec<(String, String)>) -> std::io::Result<Self> {
        // (prefix, addr) pairs → namespace.
        let mut ns = Namespace::new();
        let mut addrs = Vec::new();
        for (i, (prefix, addr)) in origins.iter().enumerate() {
            ns.register(prefix, OriginId(i)).expect("unique prefixes");
            addrs.push(addr.clone());
        }
        let ns = Arc::new(ns);
        let addrs = Arc::new(addrs);
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = spawn_listener(listener, Arc::clone(&stop), move |mut stream| {
            while let Ok(msg) = protocol::recv(&mut stream) {
                let reply = match msg {
                    Msg::Locate { path } => match ns.resolve(&path) {
                        Some(oid) => {
                            // Confirm with the origin (the paper's
                            // redirector "will query the origins").
                            let oaddr = &addrs[oid.0];
                            match protocol::request(oaddr, &Msg::Locate { path }) {
                                Ok(Msg::StatOk { .. }) => Msg::Located { addr: oaddr.clone() },
                                _ => Msg::Error("origin does not hold path".into()),
                            }
                        }
                        None => Msg::Error("no origin for path".into()),
                    },
                    other => Msg::Error(format!("unexpected {other:?}")),
                };
                if protocol::send(&mut stream, &reply).is_err() {
                    break;
                }
            }
        });
        Ok(LiveRedirector {
            addr,
            stop,
            handle: Some(handle),
        })
    }
}

impl Drop for LiveRedirector {
    fn drop(&mut self) {
        stop_listener(&self.addr, &self.stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Shared state of a live cache: the chunk-accounting state machine
/// plus the actual cached bytes.
struct LiveCacheState {
    server: CacheServer,
    /// (path, chunk_idx) → bytes. Real payloads, verifiable.
    data: HashMap<(String, u64), Vec<u8>>,
    /// path → (size, mtime) learned from the origin.
    meta: HashMap<String, (u64, u64)>,
}

/// A live cache server: serves reads, fetches misses via
/// redirector + origin, emits real UDP monitoring packets.
pub struct LiveCache {
    pub addr: String,
    pub name: String,
    state: Arc<Mutex<LiveCacheState>>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl LiveCache {
    pub fn start(
        name: &str,
        server_id: u32,
        cfg: CacheConfig,
        redirector_addr: String,
        monitor_addr: String,
    ) -> std::io::Result<Self> {
        let state = Arc::new(Mutex::new(LiveCacheState {
            server: CacheServer::new(name, cfg),
            data: HashMap::new(),
            meta: HashMap::new(),
        }));
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let st = Arc::clone(&state);
        let user_ids = Arc::new(AtomicU32::new(1));
        let file_ids = Arc::new(AtomicU32::new(1));

        let handle = spawn_listener(listener, Arc::clone(&stop), move |mut stream| {
            let mon = UdpSocket::bind("127.0.0.1:0").expect("udp socket");
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "unknown".into());
            let user_id = user_ids.fetch_add(1, Ordering::SeqCst);
            let now_us = || SimTime(clock_us());
            // Real UDP: user login (§3.2).
            let login = packets::encode(&Envelope {
                server_id,
                timestamp: now_us(),
                packet: Packet::UserLogin {
                    user_id,
                    protocol: Protocol::Xrootd,
                    ipv6: false,
                    client_host: peer,
                },
            });
            let _ = mon.send_to(&login, &monitor_addr);

            while let Ok(msg) = protocol::recv(&mut stream) {
                match msg {
                    Msg::Read { offset, len, path } => {
                        let file_id = file_ids.fetch_add(1, Ordering::SeqCst);
                        let result = serve_read(
                            &st,
                            &redirector_addr,
                            &path,
                            offset,
                            len,
                        );
                        match result {
                            Ok((payload, file_size)) => {
                                let open = packets::encode(&Envelope {
                                    server_id,
                                    timestamp: now_us(),
                                    packet: Packet::FileOpen {
                                        file_id,
                                        user_id,
                                        file_size,
                                        path: path.clone(),
                                    },
                                });
                                let _ = mon.send_to(&open, &monitor_addr);
                                let n = payload.len() as u64;
                                if protocol::send(&mut stream, &Msg::Data(payload)).is_err() {
                                    break;
                                }
                                let close = packets::encode(&Envelope {
                                    server_id,
                                    timestamp: now_us(),
                                    packet: Packet::FileClose {
                                        file_id,
                                        bytes_read: n,
                                        bytes_written: 0,
                                        read_ops: 1,
                                        write_ops: 0,
                                    },
                                });
                                let _ = mon.send_to(&close, &monitor_addr);
                            }
                            Err(e) => {
                                if protocol::send(&mut stream, &Msg::Error(e)).is_err() {
                                    break;
                                }
                            }
                        }
                    }
                    Msg::Stat { path } => {
                        let reply = match stat_via(&st, &redirector_addr, &path) {
                            Ok((size, mtime)) => Msg::StatOk { size, mtime },
                            Err(e) => Msg::Error(e),
                        };
                        if protocol::send(&mut stream, &reply).is_err() {
                            break;
                        }
                    }
                    other => {
                        let _ = protocol::send(
                            &mut stream,
                            &Msg::Error(format!("unexpected {other:?}")),
                        );
                    }
                }
            }
        });
        Ok(LiveCache {
            addr,
            name: name.to_string(),
            state,
            stop,
            handle: Some(handle),
        })
    }

    pub fn stats(&self) -> crate::cache::CacheStats {
        self.state.lock().unwrap().server.stats
    }
}

impl Drop for LiveCache {
    fn drop(&mut self) {
        stop_listener(&self.addr, &self.stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn clock_us() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

fn stat_via(
    st: &Mutex<LiveCacheState>,
    redirector: &str,
    path: &str,
) -> Result<(u64, u64), String> {
    if let Some(&meta) = st.lock().unwrap().meta.get(path) {
        return Ok(meta);
    }
    let origin_addr = locate(redirector, path)?;
    match protocol::request(&origin_addr, &Msg::Stat { path: path.into() }) {
        Ok(Msg::StatOk { size, mtime }) => {
            st.lock().unwrap().meta.insert(path.into(), (size, mtime));
            Ok((size, mtime))
        }
        Ok(Msg::Error(e)) => Err(e),
        other => Err(format!("bad stat reply: {other:?}")),
    }
}

fn locate(redirector: &str, path: &str) -> Result<String, String> {
    match protocol::request(redirector, &Msg::Locate { path: path.into() }) {
        Ok(Msg::Located { addr }) => Ok(addr),
        Ok(Msg::Error(e)) => Err(format!("redirector: {e}")),
        other => Err(format!("bad locate reply: {other:?}")),
    }
}

/// The cache's read path: local chunks, else fetch-through.
fn serve_read(
    st: &Mutex<LiveCacheState>,
    redirector: &str,
    path: &str,
    offset: u64,
    len: u64,
) -> Result<(Vec<u8>, u64), String> {
    let (size, mtime) = stat_via(st, redirector, path)?;
    if offset + len > size {
        return Err(format!("read past EOF ({offset}+{len} > {size})"));
    }
    // Plan against the chunk-accounting state machine.
    let (plan, chunk_size) = {
        let mut guard = st.lock().unwrap();
        let chunk_size = guard.server.cfg.chunk_size.as_u64().max(1);
        let plan = guard
            .server
            .plan_read(path, offset, len, size, mtime, SimTime(clock_us()));
        if !plan.fetch.is_empty() {
            guard.server.begin_fetch(path, mtime, &plan.fetch);
        }
        (plan, chunk_size)
    };

    // Fetch missing chunks from the origin (outside the lock).
    if !plan.fetch.is_empty() {
        let origin_addr = locate(redirector, path)?;
        let mut fetched = Vec::new();
        for &c in &plan.fetch {
            let c_off = c * chunk_size;
            let c_len = chunk_size.min(size - c_off);
            match protocol::request(
                &origin_addr,
                &Msg::Read { offset: c_off, len: c_len, path: path.into() },
            ) {
                Ok(Msg::Data(bytes)) if bytes.len() as u64 == c_len => {
                    // Verify content against the keystream (the
                    // CVMFS-checksum consistency guarantee).
                    if !content::verify(path, mtime, c_off, &bytes) {
                        let mut guard = st.lock().unwrap();
                        guard.server.abort_fetch(path, mtime, &plan.fetch);
                        return Err("checksum mismatch from origin".into());
                    }
                    fetched.push((c, bytes));
                }
                Ok(other) => {
                    let mut guard = st.lock().unwrap();
                    guard.server.abort_fetch(path, mtime, &plan.fetch);
                    return Err(format!("origin read failed: {other:?}"));
                }
                Err(e) => {
                    let mut guard = st.lock().unwrap();
                    guard.server.abort_fetch(path, mtime, &plan.fetch);
                    return Err(e.to_string());
                }
            }
        }
        let mut guard = st.lock().unwrap();
        // Version churn while we were fetching: a newer-version reader
        // invalidated the entry. Our commit would be discarded, so the
        // byte store must not be overwritten with stale content either.
        if guard.server.version_of(path) == Some(mtime) {
            for (c, bytes) in fetched {
                guard.data.insert((path.to_string(), c), bytes);
            }
        }
        guard
            .server
            .commit_chunks(path, mtime, &plan.fetch, SimTime(clock_us()));
    } else if !plan.join.is_empty() {
        // Another connection is fetching; spin briefly (bounded).
        for _ in 0..1_000 {
            std::thread::sleep(std::time::Duration::from_millis(2));
            let guard = st.lock().unwrap();
            if plan
                .join
                .iter()
                .all(|c| guard.data.contains_key(&(path.to_string(), *c)))
            {
                break;
            }
        }
    }

    // Assemble the requested range from cached chunks.
    let mut guard = st.lock().unwrap();
    guard.server.record_served(plan.hit_bytes, plan.miss_bytes);
    let mut out = vec![0u8; len as usize];
    let first = offset / chunk_size;
    let last = if len == 0 { first } else { (offset + len - 1) / chunk_size };
    for c in first..=last {
        let chunk = guard
            .data
            .get(&(path.to_string(), c))
            .ok_or_else(|| format!("chunk {c} missing after fetch"))?;
        let c_start = c * chunk_size;
        let lo = offset.max(c_start);
        let hi = (offset + len).min(c_start + chunk.len() as u64);
        out[(lo - offset) as usize..(hi - offset) as usize]
            .copy_from_slice(&chunk[(lo - c_start) as usize..(hi - c_start) as usize]);
    }
    Ok((out, size))
}

/// The monitoring collector daemon: a UDP socket feeding the
/// [`Collector`] → [`Bus`] → [`Aggregator`] pipeline.
pub struct CollectorDaemon {
    pub addr: String,
    state: Arc<Mutex<(Collector, Bus, Aggregator)>>,
    sub: Arc<Mutex<crate::monitoring::bus::Subscription>>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl CollectorDaemon {
    pub fn start(server_names: Vec<(u32, String)>) -> std::io::Result<Self> {
        let socket = UdpSocket::bind("127.0.0.1:0")?;
        socket.set_read_timeout(Some(std::time::Duration::from_millis(50)))?;
        let addr = socket.local_addr()?.to_string();
        let mut collector = Collector::new();
        for (id, name) in server_names {
            collector.register_server(id, name);
        }
        let mut bus = Bus::new();
        let sub = Arc::new(Mutex::new(bus.subscribe(TRANSFER_TOPIC)));
        let state = Arc::new(Mutex::new((collector, bus, Aggregator::default())));
        let stop = Arc::new(AtomicBool::new(false));
        let st = Arc::clone(&state);
        let stop2 = Arc::clone(&stop);
        let sub2 = Arc::clone(&sub);
        let handle = std::thread::spawn(move || {
            let mut buf = [0u8; 65_536];
            while !stop2.load(Ordering::SeqCst) {
                match socket.recv_from(&mut buf) {
                    Ok((n, _)) => {
                        let mut guard = st.lock().unwrap();
                        let (collector, bus, agg) = &mut *guard;
                        collector.ingest_datagram(&buf[..n], bus);
                        let mut sub = sub2.lock().unwrap();
                        agg.consume(bus, &mut sub);
                    }
                    Err(_) => continue, // timeout: re-check stop flag
                }
            }
        });
        Ok(CollectorDaemon {
            addr,
            state,
            sub,
            stop,
            handle: Some(handle),
        })
    }

    /// Total reports aggregated so far.
    pub fn reports(&self) -> u64 {
        self.state.lock().unwrap().2.reports
    }

    /// Usage of an experiment, if seen.
    pub fn experiment_bytes(&self, name: &str) -> Option<u64> {
        self.state
            .lock()
            .unwrap()
            .2
            .experiment_usage(name)
            .map(|u| u.bytes_read)
    }

    /// Collector-level stats (orphans, decode errors).
    pub fn collector_stats(&self) -> crate::monitoring::collector::CollectorStats {
        self.state.lock().unwrap().0.stats
    }
}

impl Drop for CollectorDaemon {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        drop(self.sub.lock());
    }
}
