//! Global namespace of the data federation.
//!
//! Paper §3: "Each Origin is registered to serve a subset of the global
//! namespace." Paths look like `/ospool/ligo/frames/H1/f0042.gwf`; an
//! origin registers a prefix (`/ospool/ligo`) and is authoritative for
//! everything under it. Resolution is longest-prefix match over path
//! segments, like the production federation's `scitokens`-style
//! namespace map.

use std::collections::BTreeMap;

/// Identifier of a registered origin (index into the federation's
/// origin table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OriginId(pub usize);

#[derive(Debug, Default)]
struct Node {
    children: BTreeMap<String, Node>,
    origin: Option<OriginId>,
}

/// Prefix-tree namespace: registered prefixes → origins.
#[derive(Debug, Default)]
pub struct Namespace {
    root: Node,
    registrations: usize,
}

/// Errors from registration.
#[derive(Debug, PartialEq)]
pub enum NamespaceError {
    NotAbsolute(String),
    Conflict(String),
}

impl std::fmt::Display for NamespaceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NamespaceError::NotAbsolute(p) => write!(f, "prefix must start with '/': {p:?}"),
            NamespaceError::Conflict(p) => write!(f, "prefix {p:?} already registered"),
        }
    }
}

impl std::error::Error for NamespaceError {}

/// Split a path into normalized segments (empty segments collapsed).
fn segments(path: &str) -> impl Iterator<Item = &str> {
    path.split('/').filter(|s| !s.is_empty())
}

impl Namespace {
    pub fn new() -> Self {
        Namespace::default()
    }

    /// Register `prefix` as served by `origin`. Nested prefixes are
    /// allowed (longest match wins); exact duplicates are an error.
    pub fn register(&mut self, prefix: &str, origin: OriginId) -> Result<(), NamespaceError> {
        if !prefix.starts_with('/') {
            return Err(NamespaceError::NotAbsolute(prefix.to_string()));
        }
        let mut node = &mut self.root;
        for seg in segments(prefix) {
            node = node.children.entry(seg.to_string()).or_default();
        }
        if node.origin.is_some() {
            return Err(NamespaceError::Conflict(prefix.to_string()));
        }
        node.origin = Some(origin);
        self.registrations += 1;
        Ok(())
    }

    /// Longest-prefix resolution of a path to its authoritative origin.
    pub fn resolve(&self, path: &str) -> Option<OriginId> {
        let mut node = &self.root;
        let mut best = node.origin;
        for seg in segments(path) {
            match node.children.get(seg) {
                Some(child) => {
                    node = child;
                    if node.origin.is_some() {
                        best = node.origin;
                    }
                }
                None => break,
            }
        }
        best
    }

    /// Number of registered prefixes.
    pub fn len(&self) -> usize {
        self.registrations
    }

    pub fn is_empty(&self) -> bool {
        self.registrations == 0
    }

    /// All registered prefixes with their origins (lexicographic).
    pub fn prefixes(&self) -> Vec<(String, OriginId)> {
        let mut out = Vec::new();
        fn walk(node: &Node, path: &mut String, out: &mut Vec<(String, OriginId)>) {
            if let Some(o) = node.origin {
                let p = if path.is_empty() { "/".to_string() } else { path.clone() };
                out.push((p, o));
            }
            for (seg, child) in &node.children {
                let len = path.len();
                path.push('/');
                path.push_str(seg);
                walk(child, path, out);
                path.truncate(len);
            }
        }
        walk(&self.root, &mut String::new(), &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_resolve() {
        let mut ns = Namespace::new();
        ns.register("/ospool/ligo", OriginId(0)).unwrap();
        ns.register("/osgconnect/public", OriginId(1)).unwrap();
        assert_eq!(ns.resolve("/ospool/ligo/frames/a.gwf"), Some(OriginId(0)));
        assert_eq!(ns.resolve("/osgconnect/public/u/d.tar"), Some(OriginId(1)));
        assert_eq!(ns.resolve("/ospool/other/x"), None);
        assert_eq!(ns.resolve("/"), None);
        assert_eq!(ns.len(), 2);
    }

    #[test]
    fn longest_prefix_wins() {
        let mut ns = Namespace::new();
        ns.register("/data", OriginId(0)).unwrap();
        ns.register("/data/special", OriginId(1)).unwrap();
        assert_eq!(ns.resolve("/data/a.bin"), Some(OriginId(0)));
        assert_eq!(ns.resolve("/data/special/a.bin"), Some(OriginId(1)));
        assert_eq!(ns.resolve("/data/special"), Some(OriginId(1)));
    }

    #[test]
    fn exact_prefix_is_resolvable() {
        let mut ns = Namespace::new();
        ns.register("/a/b", OriginId(3)).unwrap();
        assert_eq!(ns.resolve("/a/b"), Some(OriginId(3)));
        assert_eq!(ns.resolve("/a"), None);
    }

    #[test]
    fn duplicate_rejected() {
        let mut ns = Namespace::new();
        ns.register("/x", OriginId(0)).unwrap();
        assert_eq!(
            ns.register("/x", OriginId(1)),
            Err(NamespaceError::Conflict("/x".into()))
        );
    }

    #[test]
    fn relative_prefix_rejected() {
        let mut ns = Namespace::new();
        assert!(matches!(
            ns.register("data/x", OriginId(0)),
            Err(NamespaceError::NotAbsolute(_))
        ));
    }

    #[test]
    fn slash_normalization() {
        let mut ns = Namespace::new();
        ns.register("/a/b/", OriginId(0)).unwrap();
        assert_eq!(ns.resolve("/a//b///c"), Some(OriginId(0)));
    }

    #[test]
    fn prefixes_listing() {
        let mut ns = Namespace::new();
        ns.register("/b", OriginId(1)).unwrap();
        ns.register("/a", OriginId(0)).unwrap();
        ns.register("/a/sub", OriginId(2)).unwrap();
        let got = ns.prefixes();
        assert_eq!(
            got,
            vec![
                ("/a".to_string(), OriginId(0)),
                ("/a/sub".to_string(), OriginId(2)),
                ("/b".to_string(), OriginId(1)),
            ]
        );
    }

    #[test]
    fn property_registered_paths_resolve() {
        use crate::util::prop::check;
        check("registered prefix resolves its subtree", 100, |g| {
            let mut ns = Namespace::new();
            let depth = g.usize(1, 4);
            let mut prefix = String::new();
            for _ in 0..depth {
                prefix.push('/');
                prefix.push_str(&format!("d{}", g.u64(0, 5)));
            }
            ns.register(&prefix, OriginId(7)).unwrap();
            let file = format!("{prefix}/leaf{}", g.u64(0, 100));
            let ok = ns.resolve(&file) == Some(OriginId(7));
            (ok, format!("prefix={prefix} file={file}"))
        });
    }
}
