//! Global namespace of the data federation.
//!
//! Paper §3: "Each Origin is registered to serve a subset of the global
//! namespace." Paths look like `/ospool/ligo/frames/H1/f0042.gwf`; an
//! origin registers a prefix (`/ospool/ligo`) and is authoritative for
//! everything under it. Resolution is longest-prefix match over path
//! segments, like the production federation's `scitokens`-style
//! namespace map.

use std::collections::BTreeMap;

/// Identifier of a registered origin (index into the federation's
/// origin table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OriginId(pub usize);

#[derive(Debug, Default)]
struct Node {
    children: BTreeMap<String, Node>,
    origin: Option<OriginId>,
}

/// Prefix-tree namespace: registered prefixes → origins.
#[derive(Debug, Default)]
pub struct Namespace {
    root: Node,
    registrations: usize,
}

/// Errors from registration.
#[derive(Debug, PartialEq)]
pub enum NamespaceError {
    NotAbsolute(String),
    Conflict(String),
}

impl std::fmt::Display for NamespaceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NamespaceError::NotAbsolute(p) => write!(f, "prefix must start with '/': {p:?}"),
            NamespaceError::Conflict(p) => write!(f, "prefix {p:?} already registered"),
        }
    }
}

impl std::error::Error for NamespaceError {}

/// Split a path into normalized segments (empty segments collapsed).
fn segments(path: &str) -> impl Iterator<Item = &str> {
    path.split('/').filter(|s| !s.is_empty())
}

impl Namespace {
    pub fn new() -> Self {
        Namespace::default()
    }

    /// Register `prefix` as served by `origin`. Nested prefixes are
    /// allowed (longest match wins); exact duplicates are an error.
    pub fn register(&mut self, prefix: &str, origin: OriginId) -> Result<(), NamespaceError> {
        if !prefix.starts_with('/') {
            return Err(NamespaceError::NotAbsolute(prefix.to_string()));
        }
        let mut node = &mut self.root;
        for seg in segments(prefix) {
            node = node.children.entry(seg.to_string()).or_default();
        }
        if node.origin.is_some() {
            return Err(NamespaceError::Conflict(prefix.to_string()));
        }
        node.origin = Some(origin);
        self.registrations += 1;
        Ok(())
    }

    /// Longest-prefix resolution of a path to its authoritative origin.
    pub fn resolve(&self, path: &str) -> Option<OriginId> {
        let mut node = &self.root;
        let mut best = node.origin;
        for seg in segments(path) {
            match node.children.get(seg) {
                Some(child) => {
                    node = child;
                    if node.origin.is_some() {
                        best = node.origin;
                    }
                }
                None => break,
            }
        }
        best
    }

    /// Number of registered prefixes.
    pub fn len(&self) -> usize {
        self.registrations
    }

    pub fn is_empty(&self) -> bool {
        self.registrations == 0
    }

    /// All registered prefixes with their origins (lexicographic).
    pub fn prefixes(&self) -> Vec<(String, OriginId)> {
        let mut out = Vec::new();
        fn walk(node: &Node, path: &mut String, out: &mut Vec<(String, OriginId)>) {
            if let Some(o) = node.origin {
                let p = if path.is_empty() { "/".to_string() } else { path.clone() };
                out.push((p, o));
            }
            for (seg, child) in &node.children {
                let len = path.len();
                path.push('/');
                path.push_str(seg);
                walk(child, path, out);
                path.truncate(len);
            }
        }
        walk(&self.root, &mut String::new(), &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_resolve() {
        let mut ns = Namespace::new();
        ns.register("/ospool/ligo", OriginId(0)).unwrap();
        ns.register("/osgconnect/public", OriginId(1)).unwrap();
        assert_eq!(ns.resolve("/ospool/ligo/frames/a.gwf"), Some(OriginId(0)));
        assert_eq!(ns.resolve("/osgconnect/public/u/d.tar"), Some(OriginId(1)));
        assert_eq!(ns.resolve("/ospool/other/x"), None);
        assert_eq!(ns.resolve("/"), None);
        assert_eq!(ns.len(), 2);
    }

    #[test]
    fn longest_prefix_wins() {
        let mut ns = Namespace::new();
        ns.register("/data", OriginId(0)).unwrap();
        ns.register("/data/special", OriginId(1)).unwrap();
        assert_eq!(ns.resolve("/data/a.bin"), Some(OriginId(0)));
        assert_eq!(ns.resolve("/data/special/a.bin"), Some(OriginId(1)));
        assert_eq!(ns.resolve("/data/special"), Some(OriginId(1)));
    }

    #[test]
    fn exact_prefix_is_resolvable() {
        let mut ns = Namespace::new();
        ns.register("/a/b", OriginId(3)).unwrap();
        assert_eq!(ns.resolve("/a/b"), Some(OriginId(3)));
        assert_eq!(ns.resolve("/a"), None);
    }

    #[test]
    fn duplicate_rejected() {
        let mut ns = Namespace::new();
        ns.register("/x", OriginId(0)).unwrap();
        assert_eq!(
            ns.register("/x", OriginId(1)),
            Err(NamespaceError::Conflict("/x".into()))
        );
    }

    #[test]
    fn relative_prefix_rejected() {
        let mut ns = Namespace::new();
        assert!(matches!(
            ns.register("data/x", OriginId(0)),
            Err(NamespaceError::NotAbsolute(_))
        ));
    }

    #[test]
    fn slash_normalization() {
        let mut ns = Namespace::new();
        ns.register("/a/b/", OriginId(0)).unwrap();
        assert_eq!(ns.resolve("/a//b///c"), Some(OriginId(0)));
    }

    #[test]
    fn prefixes_listing() {
        let mut ns = Namespace::new();
        ns.register("/b", OriginId(1)).unwrap();
        ns.register("/a", OriginId(0)).unwrap();
        ns.register("/a/sub", OriginId(2)).unwrap();
        let got = ns.prefixes();
        assert_eq!(
            got,
            vec![
                ("/a".to_string(), OriginId(0)),
                ("/a/sub".to_string(), OriginId(2)),
                ("/b".to_string(), OriginId(1)),
            ]
        );
    }

    #[test]
    fn property_nested_prefix_beats_ancestor_any_insertion_order() {
        use crate::util::prop::check;
        check("longest prefix wins, insertion order irrelevant", 100, |g| {
            // An ancestor prefix and a strictly deeper one under it.
            let depth = g.usize(1, 3);
            let mut ancestor = String::new();
            for _ in 0..depth {
                ancestor.push('/');
                ancestor.push_str(&format!("d{}", g.u64(0, 4)));
            }
            let extra = g.usize(1, 3);
            let mut nested = ancestor.clone();
            for _ in 0..extra {
                nested.push('/');
                nested.push_str(&format!("n{}", g.u64(0, 4)));
            }
            // Register in both orders; resolution must not care.
            let mut forward = Namespace::new();
            forward.register(&ancestor, OriginId(0)).unwrap();
            forward.register(&nested, OriginId(1)).unwrap();
            let mut reverse = Namespace::new();
            reverse.register(&nested, OriginId(1)).unwrap();
            reverse.register(&ancestor, OriginId(0)).unwrap();

            let deep_file = format!("{nested}/leaf{}", g.u64(0, 99));
            let shallow_file = format!("{ancestor}/other{}", g.u64(0, 99));
            for ns in [&forward, &reverse] {
                if ns.resolve(&deep_file) != Some(OriginId(1)) {
                    return (false, format!("deep {deep_file} under {nested}"));
                }
                if ns.resolve(&nested) != Some(OriginId(1)) {
                    return (false, format!("exact {nested}"));
                }
                // A path under the ancestor that stays outside the
                // nested subtree ("other…" can never match the "n…"
                // segments) resolves to the ancestor.
                if ns.resolve(&shallow_file) != Some(OriginId(0)) {
                    return (false, format!("shallow {shallow_file} under {ancestor}"));
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn property_unregistered_paths_resolve_to_none() {
        use crate::util::prop::check;
        check("unregistered subtrees resolve to None", 100, |g| {
            let mut ns = Namespace::new();
            ns.register("/registered/tree", OriginId(0)).unwrap();
            // Random paths rooted outside the registered subtree.
            let mut path = format!("/other{}", g.u64(0, 9));
            for _ in 0..g.usize(0, 4) {
                path.push('/');
                path.push_str(&format!("s{}", g.u64(0, 9)));
            }
            let sibling = format!("/registered/other{}", g.u64(0, 9));
            let ok = ns.resolve(&path).is_none() && ns.resolve(&sibling).is_none();
            (ok, format!("path={path} sibling={sibling}"))
        });
    }

    #[test]
    fn property_registered_paths_resolve() {
        use crate::util::prop::check;
        check("registered prefix resolves its subtree", 100, |g| {
            let mut ns = Namespace::new();
            let depth = g.usize(1, 4);
            let mut prefix = String::new();
            for _ in 0..depth {
                prefix.push('/');
                prefix.push_str(&format!("d{}", g.u64(0, 5)));
            }
            ns.register(&prefix, OriginId(7)).unwrap();
            let file = format!("{prefix}/leaf{}", g.u64(0, 100));
            let ok = ns.resolve(&file) == Some(OriginId(7));
            (ok, format!("prefix={prefix} file={file}"))
        });
    }
}
