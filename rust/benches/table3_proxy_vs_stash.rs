//! Table 3: HTTP proxy vs StashCache percent difference in download
//! time, per site, for the 2.3 GB and 10 GB files (paper §5).
//!
//! Runs the full §4.1 DAGMan scenario (five sites serially, four
//! downloads per file) and checks every cell's *sign* against the
//! paper, plus the headline claims of §5/§6.

#[path = "harness.rs"]
mod harness;

use stashcache::report::paper;

fn main() {
    let results = harness::timed("table3 scenario", paper::run_scenario);
    println!("{}", paper::table3(&results).render());

    let mut shape = harness::Shape::new();
    let d = |site: &str, label: &str| results.pct_difference(site, label).expect("cell");

    // Paper Table 3 signs: negative ⇒ StashCache faster.
    shape.check(d("bellarmine", "p95") < 0.0, "bellarmine 2.3GB negative (paper -68.5%)");
    shape.check(d("bellarmine", "f10g") < 0.0, "bellarmine 10GB negative (paper -10.0%)");
    shape.check(
        d("syracuse", "p95").abs() < 25.0,
        "syracuse 2.3GB a near-tie (paper +0.9%)",
    );
    shape.check(d("syracuse", "f10g") < 0.0, "syracuse 10GB negative (paper -26.3%)");
    shape.check(d("colorado", "p95") > 50.0, "colorado 2.3GB strongly positive (paper +506.5%)");
    shape.check(d("colorado", "f10g") > 50.0, "colorado 10GB strongly positive (paper +245.9%)");
    shape.check(d("nebraska", "p95") < 0.0, "nebraska 2.3GB negative (paper -12.1%)");
    shape.check(d("nebraska", "f10g") < 0.0, "nebraska 10GB negative (paper -2.1%)");
    shape.check(d("chicago", "p95") > 0.0, "chicago 2.3GB positive (paper +30.6%)");
    shape.check(d("chicago", "f10g") < 0.0, "chicago 10GB negative (paper -7.7%)");

    // §5: "For most of the tests, the very large file was downloaded
    // faster with StashCache" — 4 of 5 sites negative at 10 GB.
    let negative_10g = ["bellarmine", "syracuse", "nebraska", "chicago"]
        .iter()
        .filter(|s| d(s, "f10g") < 0.0)
        .count();
    shape.check(
        negative_10g == 4,
        "10GB: StashCache wins at the four non-outlier sites",
    );
    // §6: "for small files less than 500MB, HTTP proxies provide
    // better performance" — positive %Δ at p50 for every site.
    for site in ["bellarmine", "syracuse", "colorado", "nebraska", "chicago"] {
        shape.check(
            d(site, "p01") > 0.0,
            &format!("{site}: 5.7KB file faster via HTTP proxy"),
        );
    }
    shape.finish("table3_proxy_vs_stash");
}
