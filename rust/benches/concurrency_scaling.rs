//! Concurrency scaling: 1 → 16384 simultaneous clients through the
//! event-driven session engine.
//!
//! Two measurements:
//!
//! 1. **Scaling sweep** — campaigns of 1, 4, 16, 64, 256, 1024 jobs
//!    arriving inside a 2 s window across the five §4.1 compute
//!    sites: aggregate delivered Mbps and p50/p95/p99 download time
//!    (the scenario-diversity half of the story: contention, cache
//!    coalescing, origin DTN saturation).
//! 2. **Engine throughput** — warmed-cache tiers of 1024, 4096 and
//!    16384 sessions across the ten cache sites, so every download is
//!    a pure local hit and wall time measures engine dispatch plus the
//!    component-local allocator. Asserts ≥ 300k session-events/sec at
//!    the 1024 tier (the pre-rewrite floor was 100k) and that the
//!    allocator stays O(affected) at 16384 sessions: flows re-fixed
//!    per event under 10% of the peak concurrency.
//! 3. **Thread-scaling matrix** — the sharded session engine at
//!    1/2/4/8 threads on (a) the 16384-session warmed tier and (b) a
//!    131072-job latency-bound tier whose 4 KiB files retire flows
//!    instantly, so ≥100k sessions are live at once in their
//!    startup/RTT phase. Every thread count is digest-checked
//!    bit-identical to serial; the JSON carries the speedup/efficiency
//!    curve, and 4 threads must be ≥2× serial on the 16384 tier
//!    (skipped on hosts with fewer than 4 cores).
//! 4. **Telemetry overhead** — the warmed 1024-session tier run twice
//!    from identical rebuilt state, telemetry off vs on (full registry
//!    plus a 100-deep trace ring). The on-leg must hold ≥285k
//!    session-events/sec (95% of the 300k floor) and its record
//!    digests must equal the off-leg's byte-for-byte.
//! 5. **Cold-start tier** — 16384 sessions against a never-warmed
//!    federation whose nine experiment origins are spread across nine
//!    cache sites (one each), so the all-miss traffic forms nine
//!    disjoint origin components and the generalized epoch planner
//!    shards the cold run too. Digest-checked bit-identical to serial
//!    at every thread count; 4 threads must be ≥2× serial on ≥4-core
//!    hosts, and the epoch counters must show the planner engaged.
//!
//! Emits `BENCH_concurrency.json` at the repository root for the perf
//! trajectory.

#[path = "harness.rs"]
mod harness;

use stashcache::config::defaults::paper_federation;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::sim::campaign::{self, CampaignConfig, CampaignRecord};
use stashcache::sim::workload::Catalog;
use stashcache::util::ByteSize;
use std::fmt::Write as _;
use std::time::Instant;

struct Row {
    clients: usize,
    aggregate_mbps: f64,
    p50: f64,
    p95: f64,
    p99: f64,
    peak: usize,
    joins: u64,
    events: u64,
    wall: f64,
}

struct WarmTier {
    sessions: usize,
    reps: usize,
    events: u64,
    wall: f64,
    peak: usize,
    hits: usize,
    downloads: usize,
    flows_refixed: u64,
    components_touched: u64,
    peak_component: usize,
}

struct ThreadRow {
    sessions: usize,
    threads: usize,
    wall: f64,
    events: u64,
    peak: usize,
    speedup_vs_1t: f64,
    efficiency: f64,
    digest: u64,
}

struct ColdRow {
    sessions: usize,
    threads: usize,
    wall: f64,
    events: u64,
    peak: usize,
    speedup_vs_1t: f64,
    efficiency: f64,
    epochs_engaged: u64,
    sessions_sharded: u64,
    digest: u64,
}

/// FNV-1a digest over every observable field of the transfer records,
/// in completion order — the bit-identity surface for the sharded
/// engine (same stream the determinism tests hash).
fn records_digest(records: &[CampaignRecord]) -> u64 {
    let mut buf = String::new();
    for r in records {
        let _ = write!(
            buf,
            "{}|{}|{}|{}|{}|{:?}|{}|{};",
            r.session,
            r.site,
            r.arrival.0,
            r.record.path,
            r.record.bytes,
            r.record.method,
            r.record.cache_hit,
            r.record.duration.0,
        );
    }
    stashcache::util::fnv1a(buf.as_bytes())
}

/// Build a fresh federation and serially pre-fetch the 32-file warm
/// catalog at every cache site, so a following campaign is whole-hit
/// from the first arrival (a rebuilt fed per run keeps the start state
/// identical across thread counts).
///
/// `tiny_files` clamps every catalog file to 4 KiB: transfers retire
/// almost instantly, so a 131072-job burst is latency-bound — ≥100k
/// sessions alive at once in their startup/RTT phase without ≥100k
/// simultaneous flows in the waterfill allocator.
fn warmed_fed(tiny_files: bool) -> (FedSim, Vec<String>) {
    let mut cfg = paper_federation();
    if tiny_files {
        cfg.workload.size_dist.min = ByteSize(4096);
        cfg.workload.size_dist.max = ByteSize(4096);
    }
    let mut fed = FedSim::build(cfg);
    let sites = cache_site_names(&fed);
    let catalog = Catalog::new(fed.cfg.seed, &fed.cfg.workload);
    for site in &sites {
        let idx = fed.topo.site_index(site).expect("cache site exists");
        for i in 0..32 {
            let file = catalog.file("gwosc", i);
            fed.download(idx, &file, DownloadMethod::Stash);
        }
    }
    (fed, sites)
}

fn sweep_cfg(jobs: usize) -> CampaignConfig {
    CampaignConfig {
        jobs,
        arrival_window_secs: 2.0,
        catalog_files: 256,
        zipf_s: 1.1,
        background_flows: 2,
        ..CampaignConfig::default()
    }
}

/// The ten cache sites (each serves its own workers from a local
/// cache, so warm traffic splits into per-site allocator components).
fn cache_site_names(fed: &FedSim) -> Vec<String> {
    let mut names: Vec<String> = fed
        .caches
        .keys()
        .map(|&idx| fed.topo.site_name(idx).to_string())
        .collect();
    names.sort();
    names
}

/// Warmed-tier campaign: `jobs` Poisson arrivals inside `window`
/// seconds, Zipf-popular files from a 32-file catalog, no background.
/// Telemetry is off so the throughput tiers keep measuring the bare
/// engine; the dedicated overhead section turns it back on.
fn warm_cfg(sites: Vec<String>, jobs: usize, window: f64, seed: u64) -> CampaignConfig {
    CampaignConfig {
        sites,
        jobs,
        arrival_window_secs: window,
        catalog_files: 32,
        zipf_s: 1.1,
        background_flows: 0,
        seed,
        telemetry: false,
        ..CampaignConfig::default()
    }
}

/// Federation + campaign for the cold-start tier: the nine experiment
/// origins move from Chicago to nine distinct cache sites (one each;
/// `stash-chicago` stays put), and the campaign points each of those
/// sites at its own experiment. Every site then pulls its cold misses
/// from a same-site origin DTN — the fetch route never crosses the WAN
/// — so the all-miss run splits into nine disjoint origin components
/// the epoch planner can shard. The federation is never pre-warmed:
/// wall time covers first touch to last byte.
fn cold_multi_origin(
    jobs: usize,
    window: f64,
    seed: u64,
) -> (stashcache::config::FederationConfig, CampaignConfig) {
    let mut cfg = paper_federation();
    let mut sites: Vec<String> = cfg.cache_sites().map(|s| s.name.clone()).collect();
    sites.sort();
    sites.truncate(9);
    let mut experiments: Vec<String> = Vec::new();
    for o in &mut cfg.origins {
        if let Some(exp) = o.prefix.strip_prefix("/ospool/") {
            o.site = sites[experiments.len() % sites.len()].clone();
            experiments.push(exp.to_string());
        }
    }
    assert_eq!(experiments.len(), sites.len(), "one experiment per site");
    let ccfg = CampaignConfig {
        site_experiments: experiments,
        ..warm_cfg(sites, jobs, window, seed)
    };
    (cfg, ccfg)
}

/// One telemetry-overhead leg: `reps` warmed 1024-session campaigns,
/// each on a freshly rebuilt + rewarmed federation (identical start
/// state per leg), telemetry off or on (with a 100-deep trace ring).
/// Returns the aggregate event rate and the per-rep record digests.
fn telemetry_leg(telemetry: bool, reps: usize) -> (f64, Vec<u64>) {
    let mut events = 0u64;
    let mut wall = 0.0f64;
    let mut digests = Vec::with_capacity(reps);
    for rep in 0..reps {
        let (mut fed, sites) = warmed_fed(false);
        let ccfg = CampaignConfig {
            telemetry,
            trace: if telemetry { 100 } else { 0 },
            ..warm_cfg(sites, 1024, 60.0, (100 + rep) as u64)
        };
        let start = Instant::now();
        let r = campaign::run_on(&mut fed, &ccfg);
        wall += start.elapsed().as_secs_f64();
        events += r.events_processed;
        digests.push(records_digest(&r.records));
    }
    (events as f64 / wall.max(1e-9), digests)
}

fn main() {
    let mut shape = harness::Shape::new();
    let mut rows: Vec<Row> = Vec::new();

    println!("== concurrency scaling sweep ==");
    println!(
        "{:>8} {:>14} {:>9} {:>9} {:>9} {:>6} {:>7} {:>9} {:>9}",
        "clients", "aggregate Mbps", "p50 s", "p95 s", "p99 s", "peak", "joins", "events", "evt/s"
    );
    for &n in &[1usize, 4, 16, 64, 256, 1024] {
        let ccfg = sweep_cfg(n);
        let start = Instant::now();
        let r = campaign::run(paper_federation(), &ccfg);
        let wall = start.elapsed().as_secs_f64();
        let ps = r.duration_percentiles(&[50.0, 95.0, 99.0]);
        shape.check(r.records.len() == n, &format!("{n}-client campaign completes every job"));
        println!(
            "{:>8} {:>14.0} {:>9.2} {:>9.2} {:>9.2} {:>6} {:>7} {:>9} {:>9.0}",
            n,
            r.aggregate_mbps(),
            ps[0],
            ps[1],
            ps[2],
            r.peak_concurrent,
            r.coalesced_joins,
            r.events_processed,
            r.events_processed as f64 / wall.max(1e-9),
        );
        rows.push(Row {
            clients: n,
            aggregate_mbps: r.aggregate_mbps(),
            p50: ps[0],
            p95: ps[1],
            p99: ps[2],
            peak: r.peak_concurrent,
            joins: r.coalesced_joins,
            events: r.events_processed,
            wall,
        });
    }

    let first = &rows[0];
    let last = &rows[rows.len() - 1];
    shape.check(
        last.peak >= 768,
        "1024-client campaign overlaps ≥768 sessions",
    );
    shape.check(last.joins > 0, "1024 clients on a Zipf catalog coalesce");
    shape.check(
        last.aggregate_mbps > 1_000.0,
        "1024 clients push >1 Gbps aggregate (one client cannot)",
    );
    shape.check(
        last.aggregate_mbps > first.aggregate_mbps * 0.8,
        "aggregate throughput does not collapse under concurrency",
    );
    shape.check(
        last.p95 > first.p95,
        "contention stretches the p95 download time",
    );

    // Determinism under the bench config.
    let a = campaign::run(paper_federation(), &sweep_cfg(64));
    let b = campaign::run(paper_federation(), &sweep_cfg(64));
    shape.check(a.records == b.records, "64-client campaign bit-reproducible");

    // --- engine throughput on warmed caches ------------------------------
    // Every catalog file is pre-fetched at every cache site, so the
    // timed tiers are pure local hits: wall time is session-engine
    // dispatch plus the component-local allocator, and each site's
    // traffic forms its own small allocator component.
    println!("\n== engine throughput (warmed caches, 10 sites) ==");
    let mut fed = FedSim::build(paper_federation());
    let warm_sites = cache_site_names(&fed);
    shape.check(warm_sites.len() == 10, "paper federation has ten caches");
    {
        // Deterministic warm-up: serially fetch all 32 catalog files
        // at every cache site.
        let catalog = Catalog::new(fed.cfg.seed, &fed.cfg.workload);
        for site in &warm_sites {
            let idx = fed.topo.site_index(site).expect("cache site exists");
            for i in 0..32 {
                let file = catalog.file("gwosc", i);
                fed.download(idx, &file, DownloadMethod::Stash);
            }
        }
    }

    // (sessions, arrival window secs, timed reps). The 1024 tier keeps
    // per-site utilisation below saturation (dispatch-bound; repeated
    // for a stable rate); the bigger tiers compress arrivals so tens
    // of thousands of hit flows overlap and the allocator is actually
    // exercised at scale.
    let tiers: [(usize, f64, usize); 3] = [(1024, 60.0, 8), (4096, 64.0, 2), (16384, 64.0, 1)];
    let mut warm_rows: Vec<WarmTier> = Vec::new();
    println!(
        "{:>9} {:>5} {:>10} {:>9} {:>9} {:>7} {:>12} {:>11} {:>10}",
        "sessions", "reps", "events", "wall s", "evt/s", "peak", "refix/event", "peak comp", "hit %"
    );
    for (ti, &(jobs, window, reps)) in tiers.iter().enumerate() {
        let mut events = 0u64;
        let mut wall = 0.0f64;
        let mut peak = 0usize;
        let mut hits = 0usize;
        let mut downloads = 0usize;
        let mut flows_refixed = 0u64;
        let mut components_touched = 0u64;
        let mut peak_component = 0usize;
        for rep in 0..reps {
            let ccfg = warm_cfg(
                warm_sites.clone(),
                jobs,
                window,
                (7 + ti * 16 + rep) as u64,
            );
            let start = Instant::now();
            let r = campaign::run_on(&mut fed, &ccfg);
            wall += start.elapsed().as_secs_f64();
            events += r.events_processed;
            peak = peak.max(r.peak_concurrent);
            hits += r.records.iter().filter(|c| c.record.cache_hit).count();
            downloads += r.records.len();
            flows_refixed += r.engine.flows_refixed;
            components_touched += r.engine.components_touched;
            peak_component = peak_component.max(r.engine.peak_component);
        }
        let rate = events as f64 / wall.max(1e-9);
        let refix_per_event = flows_refixed as f64 / events.max(1) as f64;
        println!(
            "{:>9} {:>5} {:>10} {:>9.3} {:>9.0} {:>7} {:>12.2} {:>11} {:>9.1}%",
            jobs,
            reps,
            events,
            wall,
            rate,
            peak,
            refix_per_event,
            peak_component,
            100.0 * hits as f64 / downloads.max(1) as f64,
        );
        shape.check(
            downloads == jobs * reps,
            &format!("{jobs}-session warmed tier completes every job"),
        );
        shape.check(
            hits * 100 >= downloads * 99,
            &format!("{jobs}-session warmed tier is ≥99% cache hits"),
        );
        if jobs == 1024 {
            shape.check(
                rate >= 300_000.0,
                "warmed 1024-session engine sustains ≥300k session-events/sec",
            );
        }
        if jobs >= 4096 {
            // The tentpole gate: allocator work per event is the size
            // of the touched component, not the active population.
            shape.check(
                refix_per_event < 0.10 * peak as f64,
                &format!(
                    "{jobs}-session allocator is component-local \
                     ({refix_per_event:.1} flows/event vs peak {peak})"
                ),
            );
        }
        if jobs == 16384 {
            shape.check(
                peak >= 4_096,
                "16384-session tier overlaps ≥4096 sessions",
            );
        }
        warm_rows.push(WarmTier {
            sessions: jobs,
            reps,
            events,
            wall,
            peak,
            hits,
            downloads,
            flows_refixed,
            components_touched,
            peak_component,
        });
    }

    // --- telemetry overhead on the warmed 1024-session tier --------------
    // Same shape as the ≥300k gate tier, but rebuilt per rep so the
    // off- and on-legs start from identical state. Telemetry must stay
    // off the bit-identity surface (digest-equal legs) and cost less
    // than 5% of the throughput floor.
    println!("\n== telemetry overhead (warmed 1024-session tier) ==");
    let telem_reps = 8usize;
    let (rate_off, digests_off) = telemetry_leg(false, telem_reps);
    let (rate_on, digests_on) = telemetry_leg(true, telem_reps);
    let overhead_pct = 100.0 * (1.0 - rate_on / rate_off.max(1e-9));
    println!(
        "telemetry off: {rate_off:.0} evt/s | on (+100-trace ring): {rate_on:.0} evt/s \
         | overhead {overhead_pct:.1}%"
    );
    shape.check(
        digests_on == digests_off,
        "telemetry on/off legs are record-digest identical",
    );
    shape.check(
        rate_on >= 285_000.0,
        "telemetry-on warmed 1024 tier sustains ≥285k session-events/sec \
         (95% of the 300k floor)",
    );

    // --- sharded engine: thread-scaling matrix ---------------------------
    // Two tiers, each run at 1/2/4/8 threads on a freshly rebuilt and
    // rewarmed federation (identical start state per thread count):
    //
    //   * 16384 sessions, real §4.2 file sizes — the speedup gate tier.
    //     Fully warmed + no faults + stable policy means the terminal
    //     epoch engages on the first engine iteration, so the whole run
    //     is one parallel epoch of ten site-local shards.
    //   * 131072 sessions, 4 KiB files, 0.5 s arrival window — the
    //     ≥100k-concurrency tier. Session lifetime is floored by the
    //     ~920 ms stashcp startup chain (tool + GeoIP + connect), so
    //     every job is still alive when the last one arrives.
    //
    // Every thread count must produce a record stream digest-identical
    // to the serial run; speedups are measured against the 1-thread leg
    // of the same tier.
    println!("\n== sharded engine: thread scaling (bit-identical) ==");
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("host parallelism: {hw}");
    println!(
        "{:>9} {:>8} {:>10} {:>9} {:>8} {:>9} {:>11} {:>18}",
        "sessions", "threads", "events", "wall s", "peak", "speedup", "efficiency", "digest"
    );
    let mut thread_rows: Vec<ThreadRow> = Vec::new();
    // (sessions, arrival window secs, tiny 4 KiB files, campaign seed)
    let matrix_tiers: [(usize, f64, bool, u64); 2] =
        [(16384, 64.0, false, 71), (131_072, 0.5, true, 72)];
    for &(jobs, window, tiny, seed) in &matrix_tiers {
        let mut base_wall = 0.0f64;
        let mut base_digest = 0u64;
        for &threads in &[1usize, 2, 4, 8] {
            let (mut fed, sites) = warmed_fed(tiny);
            let ccfg = warm_cfg(sites, jobs, window, seed);
            let start = Instant::now();
            let r = campaign::run_on_threads(&mut fed, &ccfg, threads);
            let wall = start.elapsed().as_secs_f64();
            let digest = records_digest(&r.records);
            shape.check(
                r.records.len() == jobs,
                &format!("{jobs}-session matrix tier completes every job at {threads} threads"),
            );
            if threads == 1 {
                base_wall = wall;
                base_digest = digest;
                if tiny {
                    shape.check(
                        r.peak_concurrent >= 100_000,
                        &format!(
                            "131072-session tier overlaps ≥100k concurrent sessions \
                             (peak {})",
                            r.peak_concurrent
                        ),
                    );
                }
            } else {
                shape.check(
                    digest == base_digest,
                    &format!(
                        "{jobs}-session run at {threads} threads is bit-identical to serial"
                    ),
                );
            }
            let speedup = if threads == 1 {
                1.0
            } else {
                base_wall / wall.max(1e-9)
            };
            let efficiency = speedup / threads as f64;
            if jobs == 16384 && threads == 4 && hw >= 4 {
                shape.check(
                    speedup >= 2.0,
                    &format!(
                        "16384-session warmed tier reaches ≥2× at 4 threads \
                         ({speedup:.2}×)"
                    ),
                );
            }
            println!(
                "{:>9} {:>8} {:>10} {:>9.3} {:>8} {:>8.2}x {:>11.2} {:>#18x}",
                jobs, threads, r.events_processed, wall, r.peak_concurrent, speedup, efficiency,
                digest,
            );
            thread_rows.push(ThreadRow {
                sessions: jobs,
                threads,
                wall,
                events: r.events_processed,
                peak: r.peak_concurrent,
                speedup_vs_1t: speedup,
                efficiency,
                digest,
            });
        }
    }

    // --- sharded engine: cold-start tier ---------------------------------
    // All-miss catalog, nine self-contained sites (local cache + local
    // origin each): the generalized epoch planner must shard the cold
    // run — no warm-up leg, the measured wall clock includes every
    // origin fetch. Bit-identity and the ≥2× gate mirror the warmed
    // matrix; the epoch counters prove the planner engaged rather than
    // silently falling back to the serial loop.
    println!("\n== sharded engine: cold-start scaling (multi-origin, all-miss) ==");
    println!(
        "{:>9} {:>8} {:>10} {:>9} {:>8} {:>9} {:>11} {:>7} {:>9} {:>18}",
        "sessions", "threads", "events", "wall s", "peak", "speedup", "efficiency", "epochs",
        "sharded", "digest"
    );
    let mut cold_rows: Vec<ColdRow> = Vec::new();
    {
        let jobs = 16384usize;
        let mut base_wall = 0.0f64;
        let mut base_digest = 0u64;
        for &threads in &[1usize, 2, 4, 8] {
            let (cfg, ccfg) = cold_multi_origin(jobs, 64.0, 73);
            let mut fed = FedSim::build(cfg);
            let start = Instant::now();
            let r = campaign::run_on_threads(&mut fed, &ccfg, threads);
            let wall = start.elapsed().as_secs_f64();
            let digest = records_digest(&r.records);
            shape.check(
                r.records.len() == jobs,
                &format!("{jobs}-session cold tier completes every job at {threads} threads"),
            );
            if threads == 1 {
                base_wall = wall;
                base_digest = digest;
                shape.check(
                    r.records.iter().any(|c| !c.record.cache_hit),
                    "cold tier starts all-miss (first touches are misses)",
                );
                shape.check(
                    r.epochs.epochs_engaged == 0,
                    "serial cold leg never plans epochs",
                );
            } else {
                shape.check(
                    digest == base_digest,
                    &format!("{jobs}-session cold run at {threads} threads is bit-identical to serial"),
                );
                shape.check(
                    r.epochs.epochs_engaged >= 1 && r.epochs.sessions_sharded > 0,
                    &format!(
                        "cold epochs engage at {threads} threads \
                         (engaged {}, sharded {})",
                        r.epochs.epochs_engaged, r.epochs.sessions_sharded
                    ),
                );
            }
            let speedup = if threads == 1 {
                1.0
            } else {
                base_wall / wall.max(1e-9)
            };
            let efficiency = speedup / threads as f64;
            if threads == 4 && hw >= 4 {
                shape.check(
                    speedup >= 2.0,
                    &format!("16384-session cold tier reaches ≥2× at 4 threads ({speedup:.2}×)"),
                );
            }
            println!(
                "{:>9} {:>8} {:>10} {:>9.3} {:>8} {:>8.2}x {:>11.2} {:>7} {:>9} {:>#18x}",
                jobs,
                threads,
                r.events_processed,
                wall,
                r.peak_concurrent,
                speedup,
                efficiency,
                r.epochs.epochs_engaged,
                r.epochs.sessions_sharded,
                digest,
            );
            cold_rows.push(ColdRow {
                sessions: jobs,
                threads,
                wall,
                events: r.events_processed,
                peak: r.peak_concurrent,
                speedup_vs_1t: speedup,
                efficiency,
                epochs_engaged: r.epochs.epochs_engaged,
                sessions_sharded: r.epochs.sessions_sharded,
                digest,
            });
        }
    }

    // --- BENCH_concurrency.json ------------------------------------------
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"concurrency_scaling\",\n  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"clients\": {}, \"aggregate_mbps\": {:.1}, \"p50_s\": {:.3}, \
             \"p95_s\": {:.3}, \"p99_s\": {:.3}, \"peak_concurrent\": {}, \
             \"coalesced_joins\": {}, \"sim_events\": {}, \"wall_s\": {:.4}, \
             \"events_per_sec\": {:.0}}}",
            r.clients,
            r.aggregate_mbps,
            r.p50,
            r.p95,
            r.p99,
            r.peak,
            r.joins,
            r.events,
            r.wall,
            r.events as f64 / r.wall.max(1e-9),
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n  \"warmed\": [\n");
    for (i, t) in warm_rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"sessions\": {}, \"reps\": {}, \"events\": {}, \"wall_s\": {:.4}, \
             \"events_per_sec\": {:.0}, \"peak_concurrent\": {}, \"hits\": {}, \
             \"downloads\": {}, \"flows_refixed\": {}, \"flows_refixed_per_event\": {:.3}, \
             \"components_touched\": {}, \"peak_component\": {}}}",
            t.sessions,
            t.reps,
            t.events,
            t.wall,
            t.events as f64 / t.wall.max(1e-9),
            t.peak,
            t.hits,
            t.downloads,
            t.flows_refixed,
            t.flows_refixed as f64 / t.events.max(1) as f64,
            t.components_touched,
            t.peak_component,
        );
        json.push_str(if i + 1 < warm_rows.len() { ",\n" } else { "\n" });
    }
    let _ = write!(
        json,
        "  ],\n  \"telemetry_overhead\": {{\"events_per_sec_off\": {rate_off:.0}, \
         \"events_per_sec_on\": {rate_on:.0}, \"overhead_pct\": {overhead_pct:.2}}},\n  \
         \"host_parallelism\": {hw},\n  \"threaded\": [\n"
    );
    for (i, t) in thread_rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"sessions\": {}, \"threads\": {}, \"wall_s\": {:.4}, \
             \"events\": {}, \"peak_concurrent\": {}, \"speedup_vs_1t\": {:.3}, \
             \"efficiency\": {:.3}, \"digest\": \"{:#x}\"}}",
            t.sessions,
            t.threads,
            t.wall,
            t.events,
            t.peak,
            t.speedup_vs_1t,
            t.efficiency,
            t.digest,
        );
        json.push_str(if i + 1 < thread_rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n  \"cold\": [\n");
    for (i, t) in cold_rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"sessions\": {}, \"threads\": {}, \"wall_s\": {:.4}, \
             \"events\": {}, \"peak_concurrent\": {}, \"speedup_vs_1t\": {:.3}, \
             \"efficiency\": {:.3}, \"epochs_engaged\": {}, \"sessions_sharded\": {}, \
             \"digest\": \"{:#x}\"}}",
            t.sessions,
            t.threads,
            t.wall,
            t.events,
            t.peak,
            t.speedup_vs_1t,
            t.efficiency,
            t.epochs_engaged,
            t.sessions_sharded,
            t.digest,
        );
        json.push_str(if i + 1 < cold_rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    // The repository root, independent of the bench's CWD (cargo runs
    // benches from the package root, i.e. rust/).
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_concurrency.json");
    match std::fs::write(out, &json) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => println!("\nWARNING: could not write {out}: {e}"),
    }

    shape.finish("concurrency_scaling");
}
