//! Concurrency scaling: 1 → 1024 simultaneous clients through the
//! event-driven session engine.
//!
//! Two measurements:
//!
//! 1. **Scaling sweep** — campaigns of 1, 4, 16, 64, 256, 1024 jobs
//!    arriving inside a 2 s window across the five §4.1 compute
//!    sites: aggregate delivered Mbps and p50/p95/p99 download time
//!    (the scenario-diversity half of the story: contention, cache
//!    coalescing, origin DTN saturation).
//! 2. **Engine throughput** — a warmed-cache campaign where downloads
//!    are pure hits, so wall time is engine dispatch rather than
//!    allocator physics; asserts ≥ 100k session-events/sec.
//!
//! Emits `BENCH_concurrency.json` for the perf trajectory.

#[path = "harness.rs"]
mod harness;

use stashcache::config::defaults::paper_federation;
use stashcache::federation::FedSim;
use stashcache::sim::campaign::{self, CampaignConfig};
use std::fmt::Write as _;
use std::time::Instant;

struct Row {
    clients: usize,
    aggregate_mbps: f64,
    p50: f64,
    p95: f64,
    p99: f64,
    peak: usize,
    joins: u64,
    events: u64,
    wall: f64,
}

fn sweep_cfg(jobs: usize) -> CampaignConfig {
    CampaignConfig {
        jobs,
        arrival_window_secs: 2.0,
        catalog_files: 256,
        zipf_s: 1.1,
        background_flows: 2,
        ..CampaignConfig::default()
    }
}

fn main() {
    let mut shape = harness::Shape::new();
    let mut rows: Vec<Row> = Vec::new();

    println!("== concurrency scaling sweep ==");
    println!(
        "{:>8} {:>14} {:>9} {:>9} {:>9} {:>6} {:>7} {:>9} {:>9}",
        "clients", "aggregate Mbps", "p50 s", "p95 s", "p99 s", "peak", "joins", "events", "evt/s"
    );
    for &n in &[1usize, 4, 16, 64, 256, 1024] {
        let ccfg = sweep_cfg(n);
        let start = Instant::now();
        let r = campaign::run(paper_federation(), &ccfg);
        let wall = start.elapsed().as_secs_f64();
        let ps = r.duration_percentiles(&[50.0, 95.0, 99.0]);
        shape.check(r.records.len() == n, &format!("{n}-client campaign completes every job"));
        println!(
            "{:>8} {:>14.0} {:>9.2} {:>9.2} {:>9.2} {:>6} {:>7} {:>9} {:>9.0}",
            n,
            r.aggregate_mbps(),
            ps[0],
            ps[1],
            ps[2],
            r.peak_concurrent,
            r.coalesced_joins,
            r.events_processed,
            r.events_processed as f64 / wall.max(1e-9),
        );
        rows.push(Row {
            clients: n,
            aggregate_mbps: r.aggregate_mbps(),
            p50: ps[0],
            p95: ps[1],
            p99: ps[2],
            peak: r.peak_concurrent,
            joins: r.coalesced_joins,
            events: r.events_processed,
            wall,
        });
    }

    let first = &rows[0];
    let last = &rows[rows.len() - 1];
    shape.check(
        last.peak >= 768,
        "1024-client campaign overlaps ≥768 sessions",
    );
    shape.check(last.joins > 0, "1024 clients on a Zipf catalog coalesce");
    shape.check(
        last.aggregate_mbps > 1_000.0,
        "1024 clients push >1 Gbps aggregate (one client cannot)",
    );
    shape.check(
        last.aggregate_mbps > first.aggregate_mbps * 0.8,
        "aggregate throughput does not collapse under concurrency",
    );
    shape.check(
        last.p95 > first.p95,
        "contention stretches the p95 download time",
    );

    // Determinism under the bench config.
    let a = campaign::run(paper_federation(), &sweep_cfg(64));
    let b = campaign::run(paper_federation(), &sweep_cfg(64));
    shape.check(a.records == b.records, "64-client campaign bit-reproducible");

    // --- engine throughput on a warmed cache -----------------------------
    // Cold pass warms every cache; the timed pass is pure hits, so the
    // wall clock measures session-engine dispatch.
    println!("\n== engine throughput (warmed caches) ==");
    let warm_sites = vec!["syracuse".into(), "nebraska".into(), "chicago".into()];
    let warm = CampaignConfig {
        sites: warm_sites.clone(),
        jobs: 2_048,
        arrival_window_secs: 600.0,
        catalog_files: 32,
        zipf_s: 1.1,
        background_flows: 0,
        ..CampaignConfig::default()
    };
    let mut fed = FedSim::build(paper_federation());
    let _ = campaign::run_on(&mut fed, &warm);
    let timed = CampaignConfig {
        seed: 7,
        ..warm
    };
    let start = Instant::now();
    let hot = campaign::run_on(&mut fed, &timed);
    let wall = start.elapsed().as_secs_f64();
    let rate = hot.events_processed as f64 / wall.max(1e-9);
    let hit_sessions = hot
        .records
        .iter()
        .filter(|r| r.record.cache_hit)
        .count();
    println!(
        "sessions {} | hits {} | events {} | wall {:.3}s | {:.0} session-events/s",
        hot.records.len(),
        hit_sessions,
        hot.events_processed,
        wall,
        rate
    );
    shape.check(
        hot.records.len() == 2_048,
        "warmed campaign completes every job",
    );
    shape.check(
        hit_sessions * 10 >= hot.records.len() * 9,
        "warmed pass is ≥90% cache hits",
    );
    shape.check(rate >= 100_000.0, "engine sustains ≥100k session-events/sec");

    // --- BENCH_concurrency.json ------------------------------------------
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"concurrency_scaling\",\n  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"clients\": {}, \"aggregate_mbps\": {:.1}, \"p50_s\": {:.3}, \
             \"p95_s\": {:.3}, \"p99_s\": {:.3}, \"peak_concurrent\": {}, \
             \"coalesced_joins\": {}, \"sim_events\": {}, \"wall_s\": {:.4}, \
             \"events_per_sec\": {:.0}}}",
            r.clients,
            r.aggregate_mbps,
            r.p50,
            r.p95,
            r.p99,
            r.peak,
            r.joins,
            r.events,
            r.wall,
            r.events as f64 / r.wall.max(1e-9),
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    let _ = write!(
        json,
        "  ],\n  \"engine\": {{\"sessions\": {}, \"events\": {}, \"wall_s\": {:.4}, \
         \"events_per_sec\": {:.0}}}\n}}\n",
        hot.records.len(),
        hot.events_processed,
        wall,
        rate
    );
    match std::fs::write("BENCH_concurrency.json", &json) {
        Ok(()) => println!("\nwrote BENCH_concurrency.json"),
        Err(e) => println!("\nWARNING: could not write BENCH_concurrency.json: {e}"),
    }

    shape.finish("concurrency_scaling");
}
