//! Figure 4: one year of StashCache federation usage, weekly series.
//!
//! A 52-week workload with the eyeballed production intensity profile
//! (ramp + campaign bursts) runs through the monitoring pipeline; the
//! weekly series is read from the aggregator, like the paper's
//! dashboard read the OSG database.

#[path = "harness.rs"]
mod harness;

use stashcache::report::paper;

fn main() {
    // A year at a scaled-down arrival rate (shape, not volume).
    let (chart, csv) = harness::timed("fig4", || paper::fig4(364.0, 1.2));
    println!("{chart}");
    println!("{}", csv.to_csv());

    // Parse weekly bytes back out of the CSV table for shape checks.
    let weekly: Vec<u64> = csv
        .rows
        .iter()
        .map(|r| r[1].parse().expect("bytes column"))
        .collect();
    let mut shape = harness::Shape::new();
    shape.check(weekly.len() >= 50, "about a year of weekly buckets");
    let q1: u64 = weekly.iter().take(13).sum();
    let q4: u64 = weekly.iter().rev().take(13).sum();
    shape.check(
        q4 > 2 * q1,
        "usage grows through the year (paper: visible ramp)",
    );
    let peak = *weekly.iter().max().unwrap();
    let median = {
        let mut w = weekly.clone();
        w.sort_unstable();
        w[w.len() / 2]
    };
    shape.check(
        peak > 2 * median,
        "bursty campaign weeks stand out (paper: spiky profile)",
    );
    shape.check(
        weekly.iter().filter(|&&b| b > 0).count() >= weekly.len() - 4,
        "federation is active nearly every week",
    );
    shape.finish("fig4_usage_year");
}
