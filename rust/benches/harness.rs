//! Shared bench harness (no criterion offline — DESIGN.md §2 row 17).
//!
//! Each bench target regenerates one paper artifact, prints it with
//! wall-clock timing, and asserts the *shape* the paper reports (who
//! wins, where the crossovers are). A shape violation exits non-zero
//! so `cargo bench` doubles as a reproduction regression gate.

#![allow(dead_code)]

use std::time::Instant;

pub struct Shape {
    failures: Vec<String>,
}

impl Shape {
    pub fn new() -> Self {
        Shape { failures: Vec::new() }
    }

    /// Record a shape expectation.
    pub fn check(&mut self, ok: bool, what: &str) {
        if ok {
            println!("  shape OK  {what}");
        } else {
            println!("  shape FAIL {what}");
            self.failures.push(what.to_string());
        }
    }

    /// Exit non-zero if any expectation failed.
    pub fn finish(self, bench: &str) {
        if self.failures.is_empty() {
            println!("[{bench}] all shape checks passed");
        } else {
            println!("[{bench}] {} SHAPE CHECK(S) FAILED:", self.failures.len());
            for f in &self.failures {
                println!("  - {f}");
            }
            std::process::exit(1);
        }
    }
}

/// Run and time a closure.
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let start = Instant::now();
    let out = f();
    println!("[{label}] wall time: {:.2?}", start.elapsed());
    out
}

/// Throughput helper: run `f` `iters` times, report ops/sec.
pub fn throughput(label: &str, iters: u64, mut f: impl FnMut(u64)) -> f64 {
    let start = Instant::now();
    for i in 0..iters {
        f(i);
    }
    let secs = start.elapsed().as_secs_f64();
    let rate = iters as f64 / secs;
    println!("[{label}] {iters} iters in {secs:.3}s = {rate:.0} ops/s");
    rate
}
