//! Figure 5: Syracuse WAN bandwidth before/after installing a local
//! StashCache cache (paper §4).
//!
//! "Without the StashCache, Syracuse was downloading 14.3 GB/s of
//! data. After StashCache was installed, the network bandwidth reduced
//! to 1.6 GB/s." The same workload runs twice — without and with a
//! local cache — and the site's WAN byte counter is sampled in 30-min
//! buckets, like the site's router graph in the paper.

#[path = "harness.rs"]
mod harness;

use stashcache::report::paper;

fn main() {
    let (chart, csv, install) = harness::timed("fig5", || paper::fig5(3.0, 250.0));
    println!("{chart}");
    println!("(cache installed at bucket {install})");

    let rows: Vec<(u64, String)> = csv
        .rows
        .iter()
        .map(|r| (r[1].parse().expect("bytes"), r[2].clone()))
        .collect();
    let before: u64 = rows
        .iter()
        .filter(|(_, phase)| phase == "before")
        .map(|(b, _)| b)
        .sum();
    let after: u64 = rows
        .iter()
        .filter(|(_, phase)| phase == "after")
        .map(|(b, _)| b)
        .sum();
    let reduction = before as f64 / after.max(1) as f64;
    println!("WAN bytes before {before}, after {after} — reduction {reduction:.1}x");

    let mut shape = harness::Shape::new();
    shape.check(install > 0, "install point is inside the trace");
    // Totals include the post-install warm-up (cold cache), so the
    // aggregate reduction understates the steady state; the paper's 9x
    // compares warm steady states. Require >1.5x overall and >2x in
    // steady state (checked below).
    shape.check(
        reduction > 1.5,
        &format!("WAN traffic drops substantially after install ({reduction:.1}x; paper ~9x)"),
    );
    // The drop must be visible in the steady state too, not just the
    // totals: compare the last quarter of each phase.
    let phase_rows = |phase: &str| -> Vec<u64> {
        rows.iter()
            .filter(|(_, p)| p == phase)
            .map(|(b, _)| *b)
            .collect()
    };
    let b = phase_rows("before");
    let a = phase_rows("after");
    let tail = |v: &[u64]| -> f64 {
        let n = (v.len() / 4).max(1);
        v.iter().rev().take(n).sum::<u64>() as f64 / n as f64
    };
    let steady = tail(&b) / tail(&a).max(1.0);
    shape.check(
        steady > 2.0,
        &format!("steady-state WAN rate drops with the cache warm ({steady:.1}x)"),
    );
    shape.finish("fig5_syracuse_wan");
}
