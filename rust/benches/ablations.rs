//! Ablation benches for the design choices DESIGN.md calls out.
//!
//! A. Proxy TTL — reproduces §5's "expiration of files within the HTTP
//!    proxies" and quantifies how expiry forces origin re-downloads.
//! B. CVMFS chunk size — the 24 MB choice (§3.1) vs smaller/larger
//!    chunks for partial-file reads.
//! C. Cache capacity — watermark-eviction pressure vs hit rate.
//! D. GeoIP — nearest-cache selection vs a random cache.

#[path = "harness.rs"]
mod harness;

use stashcache::client::cvmfs::CvmfsClient;
use stashcache::config::defaults::paper_federation;
use stashcache::config::{CacheConfig, ProxyConfig};
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::proxy::{MissReason, ProxyLookup, ProxyServer};
use stashcache::sim::workload::FileRef;
use stashcache::util::{ByteSize, Pcg64, SimTime};

fn main() {
    let mut shape = harness::Shape::new();
    ablate_proxy_ttl(&mut shape);
    ablate_chunk_size(&mut shape);
    ablate_cache_capacity(&mut shape);
    ablate_geoip(&mut shape);
    shape.finish("ablations");
}

/// A: sweep proxy TTL; measure expired-refetch fraction over a
/// looping workload (the paper's test loop).
fn ablate_proxy_ttl(shape: &mut harness::Shape) {
    println!("== Ablation A: proxy TTL vs expiry refetch rate ==");
    let mut rates = Vec::new();
    for ttl in [120.0, 1_800.0, 86_400.0] {
        let mut p = ProxyServer::new(
            "sq",
            ProxyConfig {
                capacity: ByteSize::gb(100),
                max_object: ByteSize::gb(1),
                ttl_secs: ttl,
                per_conn_gbps: 1.0,
            },
        );
        // Loop over 20 files repeatedly, 60 s apart, for 3 hours.
        let mut expired = 0u64;
        let mut requests = 0u64;
        let mut t = 0.0;
        while t < 3.0 * 3_600.0 {
            for i in 0..20 {
                let url = format!("/f{i}");
                requests += 1;
                match p.lookup(&url, 500_000_000, SimTime::from_secs_f64(t)) {
                    ProxyLookup::Miss { reason: MissReason::Expired, .. } => {
                        expired += 1;
                        p.commit(&url, 500_000_000, SimTime::from_secs_f64(t));
                    }
                    ProxyLookup::Miss { cacheable: true, .. } => {
                        p.commit(&url, 500_000_000, SimTime::from_secs_f64(t));
                    }
                    _ => {}
                }
                t += 60.0;
            }
        }
        let rate = expired as f64 / requests as f64;
        println!("  ttl {ttl:>8.0}s: expired refetches {:.1}%", rate * 100.0);
        rates.push(rate);
    }
    shape.check(
        rates[0] > rates[1] && rates[1] > rates[2],
        "shorter proxy TTL causes more expiry refetches (paper §5)",
    );
    shape.check(rates[2] < 0.01, "day-long TTL nearly eliminates expiry");
}

/// B: CVMFS chunk size for a partial reader (reads 10% of each file).
fn ablate_chunk_size(shape: &mut harness::Shape) {
    println!("== Ablation B: chunk size vs bytes fetched (partial reads) ==");
    let file_size: u64 = 2_400_000_000;
    let read_bytes: u64 = file_size / 10;
    let mut fetched = Vec::new();
    for chunk_mb in [4u64, 24, 96] {
        // Patch the client's chunking via a fresh client + manual math:
        // CvmfsClient has CVMFS_CHUNK fixed (matching production), so
        // compute the fetched volume analytically for the sweep and
        // verify the 24 MB case against the real client.
        let chunk = chunk_mb * 1_000_000;
        let chunks_touched = read_bytes.div_ceil(chunk) + 1; // offset straddle
        let bytes = chunks_touched * chunk;
        println!(
            "  chunk {chunk_mb:>3} MB: ~{:.2} GB fetched for a {:.2} GB read",
            bytes as f64 / 1e9,
            read_bytes as f64 / 1e9
        );
        fetched.push(bytes);
    }
    let mut client = CvmfsClient::new(ByteSize::gb(4));
    let plan = client.plan_read("/f", 0, read_bytes, file_size);
    let real: u64 = plan.remote_chunks.iter().map(|&(_, _, l)| l).sum();
    println!(
        "  real client (24 MB): {:.2} GB fetched",
        real as f64 / 1e9
    );
    shape.check(
        real <= fetched[1],
        "real 24MB client fetches no more than the analytic bound",
    );
    shape.check(
        real < file_size / 5,
        "partial reads avoid whole-file transfer (the CVMFS win, §3.1)",
    );
    shape.check(
        fetched[2] > fetched[1],
        "oversized chunks over-fetch on partial reads",
    );
}

/// C: cache capacity pressure under a Zipf re-read workload.
fn ablate_cache_capacity(shape: &mut harness::Shape) {
    println!("== Ablation C: cache capacity vs hit rate / evictions ==");
    let mut hit_rates = Vec::new();
    for cap_gb in [2u64, 20, 200] {
        let mut cfg = paper_federation();
        for s in &mut cfg.sites {
            if let Some(c) = &mut s.cache {
                *c = CacheConfig {
                    capacity: ByteSize::gb(cap_gb),
                    ..*c
                };
            }
        }
        let mut fed = FedSim::build(cfg);
        let mut rng = Pcg64::new(7, 7);
        let site = fed.topo.site_index("syracuse").unwrap();
        let zipf = stashcache::util::Zipf::new(200, 1.1);
        for _ in 0..300 {
            let i = zipf.sample(&mut rng);
            let f = FileRef {
                path: format!("/ospool/ligo/data/f{i:06}.dat"),
                size: ByteSize::mb(400 + (i % 7) * 100),
                version: 1,
            };
            fed.download(site, &f, DownloadMethod::Stash);
        }
        let c = &fed.caches[&site];
        let hits = c.stats.bytes_served_hit as f64;
        let total = (c.stats.bytes_served_hit + c.stats.bytes_served_miss) as f64;
        let hr = hits / total;
        println!(
            "  capacity {cap_gb:>3} GB: hit rate {:.1}%, evictions {}",
            hr * 100.0,
            c.stats.evictions
        );
        hit_rates.push((hr, c.stats.evictions));
    }
    shape.check(
        hit_rates[0].0 < hit_rates[2].0,
        "bigger cache ⇒ higher hit rate",
    );
    shape.check(
        hit_rates[0].1 > hit_rates[2].1,
        "smaller cache ⇒ more watermark evictions",
    );
}

/// D: GeoIP nearest-cache vs random cache selection.
///
/// Distance costs round trips: the GeoIP lookup, connection
/// establishment and redirector discovery all pay the path RTT, so
/// nearest-cache selection wins for the short/medium transfers that
/// dominate the workload (Table 2: p50 < 500 MB). (The flow model has
/// no TCP-window/RTT throughput coupling, so for multi-GB transfers a
/// distant well-provisioned cache can tie a nearby one — a documented
/// simplification, DESIGN.md §2.)
fn ablate_geoip(shape: &mut harness::Shape) {
    println!("== Ablation D: GeoIP nearest vs random cache ==");
    // Nearest: the normal path.
    let mut nearest = FedSim::build(paper_federation());
    let site = nearest.topo.site_index("bellarmine").unwrap();
    let f = |i: u64| FileRef {
        path: format!("/ospool/des/data/f{i:06}.dat"),
        size: ByteSize::mb(25),
        version: 1,
    };
    let mut t_nearest = 0.0;
    for i in 0..10 {
        t_nearest += nearest
            .download(site, &f(i), DownloadMethod::Stash)
            .duration
            .as_secs_f64();
    }
    // "Random": force the amsterdam cache by zeroing every other
    // cache's appeal — emulate by measuring a transatlantic fetch
    // through the same machinery (worst case of random selection).
    let mut cfg = paper_federation();
    cfg.sites.retain(|s| {
        s.cache.is_none() || s.name == "amsterdam" || s.worker_slots > 0
    });
    for s in &mut cfg.sites {
        if s.worker_slots > 0 && s.name != "amsterdam" {
            s.cache = None; // strip local caches so amsterdam is nearest
        }
    }
    let mut random = FedSim::build(cfg);
    let site_r = random.topo.site_index("bellarmine").unwrap();
    let mut t_random = 0.0;
    for i in 0..10 {
        t_random += random
            .download(site_r, &f(i), DownloadMethod::Stash)
            .duration
            .as_secs_f64();
    }
    println!(
        "  nearest: {t_nearest:.1}s for 10 files; farthest-random: {t_random:.1}s"
    );
    shape.check(
        t_random > t_nearest,
        "GeoIP nearest-cache beats distant selection",
    );
}
