//! Table 1: StashCache usage by experiment (paper §4).
//!
//! Regenerates the top-users table by running months-equivalent of
//! federation traffic through the monitoring pipeline (UDP packet
//! formats → collector join → bus → aggregator) and reading the table
//! back from the aggregating store, exactly as the production OSG
//! database produced the paper's numbers.

#[path = "harness.rs"]
mod harness;

use stashcache::report::paper;
use stashcache::sim::usage::UsageConfig;

fn main() {
    let ucfg = UsageConfig {
        days: 3.0,
        jobs_per_hour: Some(150.0),
        background_flows: 2,
        weekly_intensity: Vec::new(),
        wan_bucket_secs: 3_600.0,
    };
    let (table, measured) = harness::timed("table1", || paper::table1(&ucfg));
    println!("{}", table.render());

    let mut shape = harness::Shape::new();
    shape.check(measured.len() >= 8, "at least 8 experiments appear");
    shape.check(
        measured[0].0 == "gwosc",
        "Open Gravitational Wave Research is the top user (paper: 1.079 PB)",
    );
    // Ordering must broadly follow the paper's Table 1: the heavy
    // experiments above the light ones.
    let rank = |name: &str| measured.iter().position(|(n, _)| n == name).unwrap_or(99);
    for heavy in ["gwosc", "des", "minerva"] {
        for light in ["nova", "lsst", "bioinformatics", "dune"] {
            shape.check(
                rank(heavy) < rank(light),
                &format!("{heavy} ranks above {light}"),
            );
        }
    }
    // gwosc : tail ratio is ~57-92× in the paper; expect a large gap.
    let bottom = measured
        .iter()
        .find(|(n, _)| n == "dune" || n == "lsst" || n == "bioinformatics");
    if let (Some((_, top)), Some((_, low))) = (measured.first(), bottom) {
        shape.check(
            top.as_f64() > 10.0 * low.as_f64(),
            "top experiment dominates the tail by >10x",
        );
    }
    shape.finish("table1_top_users");
}
