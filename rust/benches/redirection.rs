//! Redirection-policy bench: the four cache-selection policies under
//! one identical 4k-session campaign.
//!
//! 4096 Poisson jobs across the five §4.1 compute sites pull
//! Zipf-popular files from a shared catalog, once per policy —
//! `nearest`, `least-loaded`, `consistent-hash`, `tiered` — on
//! otherwise identical federations (same seed, so the workload
//! realization is the same draw every time). Reported per policy:
//! hit ratio, origin bytes fetched upstream, aggregate Mbps,
//! p50/p95/p99 download time, peak concurrency, coalesced joins,
//! direct-to-origin fallbacks, and engine events/sec.
//!
//! Shape gates:
//! * every policy completes all 4096 downloads;
//! * `consistent-hash` fetches strictly fewer origin bytes than
//!   `nearest` — the namespace-sharding claim of the XCache CDN
//!   follow-on work: a hot file converges on one cache federation-wide
//!   instead of being fetched once per site.
//!
//! Emits `BENCH_redirection.json` at the repository root for the perf
//! trajectory.

#[path = "harness.rs"]
mod harness;

use stashcache::config::defaults::paper_federation;
use stashcache::experiment::summary::digest_records;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::redirector::{PolicyKind, ALL_POLICIES};
use stashcache::sim::campaign::{self, CampaignConfig};
use std::fmt::Write as _;
use std::time::Instant;

const JOBS: usize = 4096;

struct Row {
    policy: &'static str,
    downloads: usize,
    hit_ratio: f64,
    origin_bytes: u64,
    aggregate_mbps: f64,
    p50: f64,
    p95: f64,
    p99: f64,
    peak: usize,
    coalesced: u64,
    direct: u64,
    events: u64,
    wall: f64,
    digest: u64,
}

fn bench_cfg() -> CampaignConfig {
    CampaignConfig {
        jobs: JOBS,
        arrival_window_secs: 30.0,
        catalog_files: 512,
        zipf_s: 1.2,
        background_flows: 0,
        method: DownloadMethod::Stash,
        ..CampaignConfig::default()
    }
}

fn run_policy(policy: PolicyKind) -> Row {
    let mut cfg = paper_federation();
    cfg.redirection.policy = policy;
    let mut fed = FedSim::build(cfg);
    let ccfg = bench_cfg();
    let start = Instant::now();
    let results = campaign::run_on(&mut fed, &ccfg);
    let wall = start.elapsed().as_secs_f64();

    let downloads = results.records.len();
    let hits = results
        .records
        .iter()
        .filter(|r| r.record.cache_hit)
        .count();
    let origin_bytes: u64 = fed
        .caches
        .values()
        .map(|c| c.stats.bytes_fetched_origin)
        .sum::<u64>()
        + fed
            .proxies
            .values()
            .map(|p| p.stats.bytes_fetched_upstream)
            .sum::<u64>();
    let ps = results.duration_percentiles(&[50.0, 95.0, 99.0]);
    Row {
        policy: policy.name(),
        downloads,
        hit_ratio: hits as f64 / downloads.max(1) as f64,
        origin_bytes,
        aggregate_mbps: results.aggregate_mbps(),
        p50: ps[0],
        p95: ps[1],
        p99: ps[2],
        peak: results.peak_concurrent,
        coalesced: results.coalesced_joins,
        direct: results.engine.direct_fallbacks,
        events: results.events_processed,
        wall,
        digest: digest_records(&results.records),
    }
}

fn main() {
    println!("redirection policies @ {JOBS} concurrent sessions (identical workload draw)\n");
    let mut rows = Vec::new();
    for policy in ALL_POLICIES {
        let row = harness::timed(policy.name(), || run_policy(policy));
        println!(
            "  {:>15}: {} downloads | hit {:>5.1}% | origin {:>7.1} GB | {:>6.0} Mbps | \
             p50 {:>6.2}s p95 {:>7.2}s | peak {} | joins {} | direct {} | {:.0} events/s",
            row.policy,
            row.downloads,
            100.0 * row.hit_ratio,
            row.origin_bytes as f64 / 1e9,
            row.aggregate_mbps,
            row.p50,
            row.p95,
            row.peak,
            row.coalesced,
            row.direct,
            row.events as f64 / row.wall.max(1e-9),
        );
        rows.push(row);
    }

    let mut shape = harness::Shape::new();
    for r in &rows {
        shape.check(
            r.downloads == JOBS,
            &format!("{}: every one of the {JOBS} downloads completed", r.policy),
        );
    }
    let by_name = |name: &str| rows.iter().find(|r| r.policy == name).expect("ran");
    let nearest = by_name("nearest");
    let ch = by_name("consistent-hash");
    shape.check(
        ch.origin_bytes < nearest.origin_bytes,
        &format!(
            "consistent-hash collapses origin refetches: {:.1} GB < {:.1} GB (nearest)",
            ch.origin_bytes as f64 / 1e9,
            nearest.origin_bytes as f64 / 1e9,
        ),
    );

    // --- BENCH_redirection.json ------------------------------------------
    let mut json = String::new();
    let _ = write!(
        json,
        "{{\n  \"bench\": \"redirection\",\n  \"jobs\": {JOBS},\n  \"policies\": [\n"
    );
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"policy\": \"{}\", \"downloads\": {}, \"hit_ratio\": {:.6}, \
             \"origin_bytes\": {}, \"aggregate_mbps\": {:.1}, \"p50_s\": {:.3}, \
             \"p95_s\": {:.3}, \"p99_s\": {:.3}, \"peak_concurrent\": {}, \
             \"coalesced_joins\": {}, \"direct_fallbacks\": {}, \"events\": {}, \
             \"wall_s\": {:.4}, \"events_per_sec\": {:.0}, \"records_digest\": \"{}\"}}",
            r.policy,
            r.downloads,
            r.hit_ratio,
            r.origin_bytes,
            r.aggregate_mbps,
            r.p50,
            r.p95,
            r.p99,
            r.peak,
            r.coalesced,
            r.direct,
            r.events,
            r.wall,
            r.events as f64 / r.wall.max(1e-9),
            r.digest,
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    // The repository root, independent of the bench's CWD (cargo runs
    // benches from the package root, i.e. rust/).
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_redirection.json");
    match std::fs::write(out, &json) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => println!("\nWARNING: could not write {out}: {e}"),
    }

    shape.finish("redirection");
}
