//! Table 2: file-size percentiles of transferred files (paper §4.1).
//!
//! The percentiles come out of the monitoring aggregator's log-spaced
//! histogram — the same binning the AOT `usage_hist` Pallas kernel
//! computes — with an exact-reservoir cross-check.

#[path = "harness.rs"]
mod harness;

use stashcache::report::paper;
use stashcache::sim::usage::UsageConfig;
use stashcache::util::bytes::{GB, MB};

fn main() {
    let ucfg = UsageConfig {
        days: 3.0,
        jobs_per_hour: Some(150.0),
        background_flows: 2,
        weekly_intensity: Vec::new(),
        wan_bucket_secs: 3_600.0,
    };
    let (table, est) = harness::timed("table2", || paper::table2(&ucfg));
    println!("{}", table.render());

    let get = |p: f64| {
        est.iter()
            .find(|(pp, _)| (*pp - p).abs() < 1e-9)
            .map(|(_, b)| b.as_f64())
            .expect("percentile row")
    };
    let paper_vals = [
        (5.0, 22.801 * MB as f64),
        (25.0, 170.131 * MB as f64),
        (50.0, 467.852 * MB as f64),
        (75.0, 493.337 * MB as f64),
        (95.0, 2.335 * GB as f64),
        (99.0, 2.335 * GB as f64),
    ];
    let mut shape = harness::Shape::new();
    for (p, want) in paper_vals {
        let got = get(p);
        let ratio = got / want;
        shape.check(
            (0.4..2.5).contains(&ratio),
            &format!("p{p:.0}: {got:.3e} within ~1 bin of paper {want:.3e} (ratio {ratio:.2})"),
        );
    }
    // The distinctive features: p50 ≈ p75 (dominant mode), p95 == p99.
    shape.check(
        get(75.0) / get(50.0) < 1.6,
        "p50 and p75 nearly coincide (dominant ~480 MB mode)",
    );
    shape.check(
        get(99.0) / get(95.0) < 1.6,
        "p95 and p99 nearly coincide (pinned 2.335 GB mode)",
    );
    shape.check(get(1.0) < 10.0 * MB as f64, "p1 is a tiny file");
    shape.finish("table2_percentiles");
}
