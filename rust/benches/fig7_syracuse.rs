//! Figure 7: Syracuse cache performance (paper §5).
//!
//! "You will notice that the cached StashCache is always better than
//! the non-cached. Also, for large data transfers, StashCache is
//! faster than HTTP proxies."

#[path = "harness.rs"]
mod harness;

use stashcache::config::defaults;
use stashcache::report::paper;
use stashcache::util::bytes::GB;

fn main() {
    let results = harness::timed("fig7 scenario", paper::run_scenario);
    let (chart, csv) = paper::fig_site_performance(&results, "syracuse");
    println!("{chart}");
    println!("{}", csv.to_csv());

    let mut shape = harness::Shape::new();
    for (label, size) in defaults::test_file_sizes() {
        let cold = results
            .rate("syracuse", &label, "stash", "cold")
            .expect("cold");
        let hot = results
            .rate("syracuse", &label, "stash", "hot")
            .expect("hot");
        shape.check(
            hot >= cold * 0.999,
            &format!("{size}: cached StashCache always better than non-cached"),
        );
    }
    // Large transfers favour StashCache (mean over passes).
    let mean = |label: &str, tool: &str| results.mean_secs("syracuse", label, tool).unwrap();
    shape.check(
        mean("f10g", "stash") < mean("f10g", "http"),
        "10GB: StashCache faster than HTTP proxy",
    );
    // Small files favour the proxy.
    shape.check(
        mean("p01", "stash") > mean("p01", "http"),
        "5.7KB: HTTP proxy faster than StashCache",
    );
    shape.check(
        mean("p05", "stash") > mean("p05", "http"),
        "22.8MB: HTTP proxy faster than StashCache",
    );
    // Sanity: the 10 GB hot-stash rate exceeds 500 Mbps on a 10G LAN
    // cache (delivery is link-limited, not implementation-limited).
    let hot10 = results.rate("syracuse", "f10g", "stash", "hot").unwrap();
    shape.check(
        hot10 > 500.0,
        &format!("10GB hot delivery is fast ({hot10:.0} Mbps)"),
    );
    let _ = GB;
    shape.finish("fig7_syracuse");
}
