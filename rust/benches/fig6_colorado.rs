//! Figure 6: Colorado cache performance (paper §5).
//!
//! "Using the HTTP Proxies provide faster download speeds than using
//! StashCache in all filesizes. This could be because the HTTP proxy
//! has fast networking to the wide area network, while the worker
//! nodes have slower networking to the nearest StashCache cache."

#[path = "harness.rs"]
mod harness;

use stashcache::config::defaults;
use stashcache::report::paper;

fn main() {
    let results = harness::timed("fig6 scenario", paper::run_scenario);
    let (chart, csv) = paper::fig_site_performance(&results, "colorado");
    println!("{chart}");
    println!("{}", csv.to_csv());

    let mut shape = harness::Shape::new();
    for (label, size) in defaults::test_file_sizes() {
        let http = results
            .rate("colorado", &label, "http", "cold")
            .expect("http rate");
        let stash_cold = results
            .rate("colorado", &label, "stash", "cold")
            .expect("stash cold");
        let stash_hot = results
            .rate("colorado", &label, "stash", "hot")
            .expect("stash hot");
        // HTTP wins at every size — even against warm StashCache.
        shape.check(
            http > stash_cold && http > stash_hot,
            &format!("{size}: HTTP proxy beats StashCache (cold and hot)"),
        );
        shape.check(
            stash_hot >= stash_cold * 0.999,
            &format!("{size}: cached StashCache >= cold StashCache"),
        );
    }
    shape.finish("fig6_colorado");
}
