//! Performance micro-benches for the §Perf pass (EXPERIMENTS.md).
//!
//! Hot paths: the flow allocator (every transfer start/finish), the
//! cache read planner (every request), the monitoring codec+collector
//! (every open/close), the GeoIP scorer (every stashcp startup; both
//! the rust and the PJRT-artifact backends), and whole downloads
//! end-to-end.

#[path = "harness.rs"]
mod harness;

use stashcache::cache::CacheServer;
use stashcache::config::defaults::paper_federation;
use stashcache::config::CacheConfig;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::geoip::{GeoScoreBackend, RustGeoBackend};
use stashcache::monitoring::bus::Bus;
use stashcache::monitoring::collector::Collector;
use stashcache::monitoring::packets::{self, Envelope, Packet};
use stashcache::netsim::{FlowSpec, Network};
use stashcache::runtime::{GeoScorer, Runtime};
use stashcache::sim::workload::FileRef;
use stashcache::util::{ByteSize, Pcg64, SimTime};

fn main() {
    let mut shape = harness::Shape::new();

    // --- netsim: flow churn ------------------------------------------------
    {
        let mut net = Network::new();
        let links: Vec<_> = (0..40).map(|_| net.add_link_gbps(10.0)).collect();
        let mut rng = Pcg64::new(1, 1);
        let mut t = SimTime::ZERO;
        let rate = harness::throughput("netsim flow churn (~30 active)", 30_000, |i| {
            let path = vec![
                links[(i % 40) as usize],
                links[((i * 7 + 3) % 40) as usize],
            ];
            net.start_flow(
                FlowSpec { path, bytes: 1 + rng.gen_range(1_000, 1_000_000), rate_cap: None },
                t,
            );
            // Keep a bounded concurrent set: drain completions down to
            // 20 whenever the population exceeds 40.
            while net.active_flows() > 40 {
                let tc = net.next_completion().expect("active flows");
                t = tc;
                net.advance(tc);
            }
        });
        shape.check(rate > 20_000.0, "netsim sustains >20k flow ops/s");
    }

    // --- netsim: event processing ------------------------------------------
    {
        let mut net = Network::new();
        let link = net.add_link_gbps(10.0);
        let mut events = 0u64;
        let start = std::time::Instant::now();
        let mut t = SimTime::ZERO;
        for _ in 0..50_000 {
            net.start_flow(
                FlowSpec { path: vec![link], bytes: 1_000_000, rate_cap: None },
                t,
            );
            while let Some(tc) = net.next_completion() {
                t = tc;
                events += net.advance(tc).len() as u64;
            }
        }
        let secs = start.elapsed().as_secs_f64();
        println!(
            "[netsim completions] {events} completions in {secs:.3}s = {:.0}/s",
            events as f64 / secs
        );
        shape.check(events as f64 / secs > 100_000.0, "netsim >100k completions/s");
    }

    // --- cache planner -------------------------------------------------------
    {
        let mut cache = CacheServer::new(
            "bench",
            CacheConfig {
                capacity: ByteSize::tb(8),
                ..CacheConfig::default()
            },
        );
        let mut rng = Pcg64::new(2, 2);
        let rate = harness::throughput("cache plan_read+commit", 100_000, |i| {
            let path = format!("/f{}", rng.gen_range(0, 2_000));
            let size = 2_400_000_000u64;
            let off = rng.gen_range(0, size - 1_000);
            let now = SimTime(i);
            let plan = cache.plan_read(&path, off, 1_000, size, 1, now);
            if !plan.fetch.is_empty() {
                cache.begin_fetch(&path, 1, &plan.fetch);
                cache.commit_chunks(&path, 1, &plan.fetch, now);
            }
        });
        shape.check(rate > 100_000.0, "cache planner >100k reqs/s");
    }

    // --- monitoring codec + collector ---------------------------------------
    {
        let mut collector = Collector::new();
        collector.register_server(1, "bench");
        let mut bus = Bus::new();
        let mut sub = bus.subscribe(stashcache::monitoring::collector::TRANSFER_TOPIC);
        let rate = harness::throughput("monitoring open+close join", 100_000, |i| {
            let open = packets::encode(&Envelope {
                server_id: 1,
                timestamp: SimTime(i),
                packet: Packet::FileOpen {
                    file_id: i as u32,
                    user_id: 1,
                    file_size: 1_000,
                    path: "/ospool/ligo/f".into(),
                },
            });
            let close = packets::encode(&Envelope {
                server_id: 1,
                timestamp: SimTime(i + 1),
                packet: Packet::FileClose {
                    file_id: i as u32,
                    bytes_read: 1_000,
                    bytes_written: 0,
                    read_ops: 1,
                    write_ops: 0,
                },
            });
            collector.ingest_datagram(&open, &mut bus);
            collector.ingest_datagram(&close, &mut bus);
            while sub.recv(&mut bus).is_some() {}
            if i % 1024 == 0 {
                bus.compact(stashcache::monitoring::collector::TRANSFER_TOPIC);
            }
        });
        // One login missing → all reports say "unknown"; that's fine
        // for throughput purposes.
        shape.check(rate > 100_000.0, "collector >100k transfer joins/s");
    }

    // --- GeoIP scorers: rust vs PJRT artifact --------------------------------
    {
        let cfg = paper_federation();
        let caches: Vec<stashcache::geoip::CacheSite> = cfg
            .cache_sites()
            .map(|s| stashcache::geoip::CacheSite {
                name: s.name.clone(),
                lat: s.lat,
                lon: s.lon,
            })
            .collect();
        let loads = vec![0.1; caches.len()];
        let clients: Vec<(f64, f64)> = (0..64).map(|i| (30.0 + i as f64 * 0.3, -100.0)).collect();

        let mut rust_backend = RustGeoBackend;
        let rust_rate = harness::throughput("geo score rust (64-client batch)", 2_000, |_| {
            let _ = rust_backend.score(&clients, &caches, &loads);
        });

        match Runtime::try_available() {
            Some(rt) => {
                let mut pjrt = GeoScorer::load(&rt).expect("geo_score artifact");
                let cache_coords: Vec<(f64, f64)> =
                    caches.iter().map(|c| (c.lat, c.lon)).collect();
                let pjrt_rate =
                    harness::throughput("geo score PJRT (64-client batch)", 2_000, |_| {
                        let _ = GeoScorer::score(&mut pjrt, &clients, &cache_coords, &loads);
                    });
                println!(
                    "  PJRT/rust batch-rate ratio: {:.2} (compiled artifact overhead)",
                    pjrt_rate / rust_rate
                );
                shape.check(
                    pjrt_rate > 200.0,
                    "PJRT geo scorer sustains >200 64-client batches/s",
                );
            }
            None => println!("  [skipped] PJRT geo scorer (runtime unavailable)"),
        }
    }

    // --- end-to-end downloads -------------------------------------------------
    {
        let mut fed = FedSim::build(paper_federation());
        let site = fed.topo.site_index("syracuse").unwrap();
        let mut rng = Pcg64::new(3, 3);
        let rate = harness::throughput("fedsim end-to-end downloads", 5_000, |_| {
            let i = rng.gen_range(0, 500);
            let f = FileRef {
                path: format!("/ospool/gwosc/data/f{i:06}.dat"),
                size: ByteSize::mb(100),
                version: 1,
            };
            fed.download(site, &f, DownloadMethod::Stash);
        });
        shape.check(rate > 2_000.0, "end-to-end >2k simulated downloads/s");
    }

    shape.finish("perf_micro");
}
