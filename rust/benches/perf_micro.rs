//! Performance micro-benches for the §Perf pass (EXPERIMENTS.md).
//!
//! Hot paths: the flow allocator (every transfer start/finish), the
//! cache read planner (every request), the monitoring codec+collector
//! (every open/close), the GeoIP scorer (every stashcp startup; both
//! the rust and the PJRT-artifact backends), and whole downloads
//! end-to-end.
//!
//! The allocator-scaling section churns 1k/4k/16k concurrent flows
//! through a star of 32 disjoint single-link "sites" (the warm-traffic
//! shape of the federation topology) and emits `BENCH_netsim.json` at
//! the repository root: events/s, allocator passes, and flows-touched
//! per event — the perf-trajectory evidence that the component-local
//! allocator costs O(affected component), not O(active flows).

#[path = "harness.rs"]
mod harness;

use stashcache::cache::CacheServer;
use stashcache::config::defaults::paper_federation;
use stashcache::config::CacheConfig;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::geoip::{GeoScoreBackend, RustGeoBackend};
use stashcache::monitoring::bus::Bus;
use stashcache::monitoring::collector::Collector;
use stashcache::monitoring::packets::{self, Envelope, Packet};
use stashcache::netsim::{FlowSpec, Network};
use stashcache::runtime::{GeoScorer, Runtime};
use stashcache::sim::workload::FileRef;
use stashcache::util::{ByteSize, Pcg64, SimTime};
use std::fmt::Write as _;

fn main() {
    let mut shape = harness::Shape::new();

    // --- netsim: flow churn ------------------------------------------------
    {
        let mut net = Network::new();
        let links: Vec<_> = (0..40).map(|_| net.add_link_gbps(10.0)).collect();
        let mut rng = Pcg64::new(1, 1);
        let mut t = SimTime::ZERO;
        let rate = harness::throughput("netsim flow churn (~30 active)", 30_000, |i| {
            let path = vec![
                links[(i % 40) as usize],
                links[((i * 7 + 3) % 40) as usize],
            ];
            net.start_flow(
                FlowSpec { path, bytes: 1 + rng.gen_range(1_000, 1_000_000), rate_cap: None },
                t,
            );
            // Keep a bounded concurrent set: drain completions down to
            // 20 whenever the population exceeds 40.
            while net.active_flows() > 40 {
                let tc = net.next_completion().expect("active flows");
                t = tc;
                net.advance(tc);
            }
        });
        shape.check(rate > 20_000.0, "netsim sustains >20k flow ops/s");
    }

    // --- netsim: event processing ------------------------------------------
    {
        let mut net = Network::new();
        let link = net.add_link_gbps(10.0);
        let mut events = 0u64;
        let start = std::time::Instant::now();
        let mut t = SimTime::ZERO;
        for _ in 0..50_000 {
            net.start_flow(
                FlowSpec { path: vec![link], bytes: 1_000_000, rate_cap: None },
                t,
            );
            while let Some(tc) = net.next_completion() {
                t = tc;
                events += net.advance(tc).len() as u64;
            }
        }
        let secs = start.elapsed().as_secs_f64();
        println!(
            "[netsim completions] {events} completions in {secs:.3}s = {:.0}/s",
            events as f64 / secs
        );
        shape.check(events as f64 / secs > 100_000.0, "netsim >100k completions/s");
    }

    // --- netsim: component-local allocator scaling ---------------------------
    // 32 disjoint single-link components (the shape warm federation
    // traffic takes: one per site), 1k/4k/16k concurrent flows churned
    // to steady state. The per-event allocator cost is the touched
    // component (~flows/32), not the population — asserted below and
    // recorded in BENCH_netsim.json as the perf trajectory's first
    // point.
    {
        struct Tier {
            flows: usize,
            events: u64,
            wall: f64,
            allocations: u64,
            components_touched: u64,
            flows_refixed: u64,
            peak_component: usize,
        }
        const SITES: usize = 32;
        let mut tiers: Vec<Tier> = Vec::new();
        println!("[netsim allocator scaling] {SITES} disjoint components");
        for &n in &[1_024usize, 4_096, 16_384] {
            let mut net = Network::new();
            let links: Vec<_> = (0..SITES).map(|_| net.add_link_gbps(100.0)).collect();
            let mut rng = Pcg64::new(9, n as u64);
            // Fill to n concurrent flows, round-robin across sites.
            let mut site_of: std::collections::HashMap<stashcache::netsim::FlowId, usize> =
                std::collections::HashMap::with_capacity(n);
            let mut t = SimTime::ZERO;
            for i in 0..n {
                let id = net.start_flow(
                    FlowSpec {
                        path: vec![links[i % SITES]],
                        bytes: rng.gen_range(1_000_000, 10_000_000),
                        rate_cap: None,
                    },
                    t,
                );
                site_of.insert(id, i % SITES);
            }
            // Steady-state churn: every completion is replaced at the
            // same site and instant, holding each site at n/SITES.
            let before = net.stats;
            let target_events = (3 * n as u64).min(60_000);
            let mut events = 0u64;
            let start = std::time::Instant::now();
            while events < target_events {
                let tc = net.next_completion().expect("population is never empty");
                t = tc;
                for done in net.advance(tc) {
                    events += 1; // completion
                    let site = site_of.remove(&done.flow).expect("tracked flow");
                    let id = net.start_flow(
                        FlowSpec {
                            path: vec![links[site]],
                            bytes: rng.gen_range(1_000_000, 10_000_000),
                            rate_cap: None,
                        },
                        t,
                    );
                    site_of.insert(id, site);
                    events += 1; // respawn
                }
            }
            let wall = start.elapsed().as_secs_f64();
            let d_alloc = net.stats.allocations - before.allocations;
            let d_comps = net.stats.components_touched - before.components_touched;
            let d_refixed = net.stats.flows_refixed - before.flows_refixed;
            let touched_per_event = d_refixed as f64 / events.max(1) as f64;
            println!(
                "  {n:>6} flows: {events} events in {wall:.3}s = {:.0}/s | {d_alloc} passes | \
                 {:.1} flows/event ({:.1}% of active) | peak component {}",
                events as f64 / wall.max(1e-9),
                touched_per_event,
                100.0 * touched_per_event / n as f64,
                net.stats.peak_component,
            );
            shape.check(
                net.active_flows() == n,
                "churn holds the population constant",
            );
            shape.check(
                touched_per_event < 0.10 * n as f64,
                "allocator touches <10% of active flows per event",
            );
            shape.check(
                net.stats.peak_component <= n / SITES + 1,
                "components never exceed one site's flows",
            );
            tiers.push(Tier {
                flows: n,
                events,
                wall,
                allocations: d_alloc,
                components_touched: d_comps,
                flows_refixed: d_refixed,
                peak_component: net.stats.peak_component,
            });
        }
        shape.check(
            tiers[0].events as f64 / tiers[0].wall.max(1e-9) > 50_000.0,
            "1k-flow churn sustains >50k events/s",
        );

        // --- BENCH_netsim.json (repo root, CWD-independent) ---------------
        let mut json = String::new();
        json.push_str("{\n  \"bench\": \"netsim_allocator\",\n");
        let _ = writeln!(json, "  \"sites\": {SITES},");
        json.push_str("  \"tiers\": [\n");
        for (i, t) in tiers.iter().enumerate() {
            let _ = write!(
                json,
                "    {{\"flows\": {}, \"events\": {}, \"wall_s\": {:.4}, \
                 \"events_per_sec\": {:.0}, \"allocator_passes\": {}, \
                 \"components_touched\": {}, \"flows_refixed\": {}, \
                 \"flows_touched_per_event\": {:.2}, \"peak_component\": {}}}",
                t.flows,
                t.events,
                t.wall,
                t.events as f64 / t.wall.max(1e-9),
                t.allocations,
                t.components_touched,
                t.flows_refixed,
                t.flows_refixed as f64 / t.events.max(1) as f64,
                t.peak_component,
            );
            json.push_str(if i + 1 < tiers.len() { ",\n" } else { "\n" });
        }
        json.push_str("  ]\n}\n");
        let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_netsim.json");
        match std::fs::write(out, &json) {
            Ok(()) => println!("  wrote {out}"),
            Err(e) => println!("  WARNING: could not write {out}: {e}"),
        }
    }

    // --- cache planner -------------------------------------------------------
    {
        let mut cache = CacheServer::new(
            "bench",
            CacheConfig {
                capacity: ByteSize::tb(8),
                ..CacheConfig::default()
            },
        );
        let mut rng = Pcg64::new(2, 2);
        let rate = harness::throughput("cache plan_read+commit", 100_000, |i| {
            let path = format!("/f{}", rng.gen_range(0, 2_000));
            let size = 2_400_000_000u64;
            let off = rng.gen_range(0, size - 1_000);
            let now = SimTime(i);
            let plan = cache.plan_read(&path, off, 1_000, size, 1, now);
            if !plan.fetch.is_empty() {
                cache.begin_fetch(&path, 1, &plan.fetch);
                cache.commit_chunks(&path, 1, &plan.fetch, now);
            }
        });
        shape.check(rate > 100_000.0, "cache planner >100k reqs/s");
    }

    // --- monitoring codec + collector ---------------------------------------
    {
        let mut collector = Collector::new();
        collector.register_server(1, "bench");
        let mut bus = Bus::new();
        let mut sub = bus.subscribe(stashcache::monitoring::collector::TRANSFER_TOPIC);
        let rate = harness::throughput("monitoring open+close join", 100_000, |i| {
            let open = packets::encode(&Envelope {
                server_id: 1,
                timestamp: SimTime(i),
                packet: Packet::FileOpen {
                    file_id: i as u32,
                    user_id: 1,
                    file_size: 1_000,
                    path: "/ospool/ligo/f".into(),
                },
            });
            let close = packets::encode(&Envelope {
                server_id: 1,
                timestamp: SimTime(i + 1),
                packet: Packet::FileClose {
                    file_id: i as u32,
                    bytes_read: 1_000,
                    bytes_written: 0,
                    read_ops: 1,
                    write_ops: 0,
                },
            });
            collector.ingest_datagram(&open, &mut bus);
            collector.ingest_datagram(&close, &mut bus);
            while sub.recv(&mut bus).is_some() {}
            if i % 1024 == 0 {
                bus.compact(stashcache::monitoring::collector::TRANSFER_TOPIC);
            }
        });
        // One login missing → all reports say "unknown"; that's fine
        // for throughput purposes.
        shape.check(rate > 100_000.0, "collector >100k transfer joins/s");
    }

    // --- GeoIP scorers: rust vs PJRT artifact --------------------------------
    {
        let cfg = paper_federation();
        let caches: Vec<stashcache::geoip::CacheSite> = cfg
            .cache_sites()
            .map(|s| stashcache::geoip::CacheSite {
                name: s.name.clone(),
                lat: s.lat,
                lon: s.lon,
            })
            .collect();
        let loads = vec![0.1; caches.len()];
        let clients: Vec<(f64, f64)> = (0..64).map(|i| (30.0 + i as f64 * 0.3, -100.0)).collect();

        let mut rust_backend = RustGeoBackend;
        let rust_rate = harness::throughput("geo score rust (64-client batch)", 2_000, |_| {
            let _ = rust_backend.score(&clients, &caches, &loads);
        });

        match Runtime::try_available() {
            Some(rt) => {
                let mut pjrt = GeoScorer::load(&rt).expect("geo_score artifact");
                let cache_coords: Vec<(f64, f64)> =
                    caches.iter().map(|c| (c.lat, c.lon)).collect();
                let pjrt_rate =
                    harness::throughput("geo score PJRT (64-client batch)", 2_000, |_| {
                        let _ = GeoScorer::score(&mut pjrt, &clients, &cache_coords, &loads);
                    });
                println!(
                    "  PJRT/rust batch-rate ratio: {:.2} (compiled artifact overhead)",
                    pjrt_rate / rust_rate
                );
                shape.check(
                    pjrt_rate > 200.0,
                    "PJRT geo scorer sustains >200 64-client batches/s",
                );
            }
            None => println!("  [skipped] PJRT geo scorer (runtime unavailable)"),
        }
    }

    // --- end-to-end downloads -------------------------------------------------
    {
        let mut fed = FedSim::build(paper_federation());
        let site = fed.topo.site_index("syracuse").unwrap();
        let mut rng = Pcg64::new(3, 3);
        let rate = harness::throughput("fedsim end-to-end downloads", 5_000, |_| {
            let i = rng.gen_range(0, 500);
            let f = FileRef {
                path: format!("/ospool/gwosc/data/f{i:06}.dat"),
                size: ByteSize::mb(100),
                version: 1,
            };
            fed.download(site, &f, DownloadMethod::Stash);
        });
        shape.check(rate > 2_000.0, "end-to-end >2k simulated downloads/s");
    }

    shape.finish("perf_micro");
}
