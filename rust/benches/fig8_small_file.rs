//! Figure 8: small-file (5.797 KB) performance across all sites
//! (paper §5).
//!
//! "For this small of a file, HTTP performance is much better than
//! StashCache. stashcp has a larger startup time which decreases its
//! average performance. The stashcp has to determine the nearest
//! cache, which requires querying a remote server."

#[path = "harness.rs"]
mod harness;

use stashcache::config::defaults::COMPUTE_SITES;
use stashcache::report::paper;

fn main() {
    let results = harness::timed("fig8 scenario", paper::run_scenario);
    let (chart, csv) = paper::fig8_small_file(&results);
    println!("{chart}");
    println!("{}", csv.to_csv());

    let mut shape = harness::Shape::new();
    for site in COMPUTE_SITES {
        let http_hot = results.rate(site, "p01", "http", "hot").expect("http hot");
        let stash_best = results
            .rate(site, "p01", "stash", "hot")
            .expect("stash hot")
            .max(results.rate(site, "p01", "stash", "cold").expect("stash cold"));
        shape.check(
            http_hot > 3.0 * stash_best,
            &format!("{site}: HTTP much better than StashCache for 5.7KB"),
        );
    }
    // The startup-latency mechanism: stashcp's effective rate on a tiny
    // file is dominated by ~1s of fixed cost → well under 1 Mbps.
    for site in COMPUTE_SITES {
        let stash = results.rate(site, "p01", "stash", "hot").unwrap();
        shape.check(
            stash < 1.0,
            &format!("{site}: stashcp 5.7KB rate is startup-bound ({stash:.3} Mbps)"),
        );
    }
    shape.finish("fig8_small_file");
}
