//! End-to-end driver on a REAL workload: the whole stack, live.
//!
//! This is the repository's integration proof (EXPERIMENTS.md §E2E):
//! every layer composes on real I/O, no simulation —
//!
//! * an **origin** serving deterministic verifiable bytes over TCP,
//! * a **redirector** doing data discovery,
//! * two **caches** at real OSG coordinates, fetching misses through
//!   the redirector and emitting the §3.2 binary UDP monitoring
//!   packets,
//! * a **collector daemon** joining those packets into transfer
//!   reports on the message bus,
//! * **stashcp** clients at three "sites" choosing caches by GeoIP
//!   (scored by the same formula the AOT Pallas kernel computes),
//!   downloading, and checksum-verifying every byte.
//!
//! Reports throughput and hit-rate, then asserts the books balance:
//! bytes served == bytes verified == bytes the monitoring pipeline
//! accounted.
//!
//! ```text
//! cargo run --release --example live_federation
//! ```

use stashcache::config::CacheConfig;
use stashcache::live::client::LiveCacheEndpoint;
use stashcache::live::{stashcp_live, CollectorDaemon, LiveCache, LiveOrigin, LiveRedirector};
use stashcache::util::ByteSize;
use std::time::Instant;

fn main() {
    // Dataset: 12 files, 1-24 MB (keeps the demo quick but multi-chunk).
    let files: Vec<(String, u64)> = (0..12)
        .map(|i| {
            (
                format!("/ospool/gwosc/strain/seg{i:03}.hdf5"),
                1_000_000 + (i as u64 % 4) * 7_500_000,
            )
        })
        .collect();
    let file_refs: Vec<(&str, u64, u64)> =
        files.iter().map(|(p, s)| (p.as_str(), *s, 1u64)).collect();

    let origin = LiveOrigin::start("stash-chicago", "/ospool/gwosc", &file_refs).unwrap();
    let redirector =
        LiveRedirector::start(vec![("/ospool/gwosc".into(), origin.addr.clone())]).unwrap();
    let monitor = CollectorDaemon::start(vec![
        (0, "nebraska".into()),
        (1, "i2-newyork".into()),
    ])
    .unwrap();
    let cache_cfg = CacheConfig {
        capacity: ByteSize::gb(2),
        chunk_size: ByteSize::mb(4),
        ..Default::default()
    };
    let c_neb = LiveCache::start("nebraska", 0, cache_cfg, redirector.addr.clone(), monitor.addr.clone()).unwrap();
    let c_nyc = LiveCache::start("i2-newyork", 1, cache_cfg, redirector.addr.clone(), monitor.addr.clone()).unwrap();
    println!(
        "live federation: origin {}, redirector {}, caches {} {}, collector {} (UDP)",
        origin.addr, redirector.addr, c_neb.addr, c_nyc.addr, monitor.addr
    );

    let endpoints = vec![
        LiveCacheEndpoint {
            site: stashcache::geoip::CacheSite { name: "nebraska".into(), lat: 40.8202, lon: -96.7005 },
            addr: c_neb.addr.clone(),
        },
        LiveCacheEndpoint {
            site: stashcache::geoip::CacheSite { name: "i2-newyork".into(), lat: 40.7128, lon: -74.0060 },
            addr: c_nyc.addr.clone(),
        },
    ];

    // Three client "sites": Boulder, Syracuse, Louisville.
    let client_sites = [
        ("colorado", 40.0076, -105.2659, "nebraska"),
        ("syracuse", 43.0392, -76.1351, "i2-newyork"),
        ("bellarmine", 38.2186, -85.7123, "nebraska"),
    ];

    let start = Instant::now();
    let mut transfers = 0u64;
    let mut bytes = 0u64;
    // Two passes: cold then hot, like §4.1.
    for pass in ["cold", "hot"] {
        for (site, lat, lon, expect_cache) in client_sites {
            for (path, size) in &files {
                let t = stashcp_live(path, lat, lon, &endpoints).expect("download");
                assert!(t.verified, "content checksum must verify");
                assert_eq!(t.bytes.len() as u64, *size);
                assert_eq!(
                    t.cache_used, expect_cache,
                    "{site} must route to its nearest cache"
                );
                transfers += 1;
                bytes += size;
                let _ = pass;
            }
        }
    }
    let wall = start.elapsed();
    println!(
        "moved {} in {} verified transfers over real TCP in {:.2?} ({:.1} MB/s end-to-end)",
        ByteSize(bytes),
        transfers,
        wall,
        bytes as f64 / 1e6 / wall.as_secs_f64()
    );

    // Hit accounting: pass 2 must be all cache hits.
    let neb = c_neb.stats();
    let nyc = c_nyc.stats();
    let served_hit = neb.bytes_served_hit + nyc.bytes_served_hit;
    let fetched = neb.bytes_fetched_origin + nyc.bytes_fetched_origin;
    println!(
        "caches: {} hit bytes, {} fetched from origin; origin served {}",
        ByteSize(served_hit),
        ByteSize(fetched),
        ByteSize(origin.bytes_served())
    );
    assert_eq!(fetched, origin.bytes_served(), "origin books must balance");
    assert!(served_hit >= bytes / 2 - 1_000_000, "second pass must hit");

    // Monitoring books: collector must have joined every transfer.
    for _ in 0..50 {
        if monitor.reports() >= transfers {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    println!(
        "monitoring: {} reports (expected {}), gwosc usage {:?}, collector stats {:?}",
        monitor.reports(),
        transfers,
        monitor.experiment_bytes("gwosc").map(ByteSize),
        monitor.collector_stats()
    );
    assert_eq!(monitor.reports(), transfers, "every transfer monitored");
    assert_eq!(
        monitor.experiment_bytes("gwosc"),
        Some(bytes),
        "aggregated usage equals bytes moved"
    );
    println!("live federation e2e OK");
}
