//! LIGO-style workflow (the workload that motivated StashCache — the
//! paper cites its own LIGO-on-OSG study [22]).
//!
//! A gravitational-wave search reads the same calibrated frame files
//! from thousands of jobs across sites. This example runs a small
//! campaign: 60 jobs at three sites, each reading a shared set of
//! frame files via CVMFS-chunked partial reads and stashcp whole-file
//! transfers, and shows the cache converting WAN traffic into LAN
//! traffic as the working set gets hot.
//!
//! ```text
//! cargo run --release --example ligo_workflow
//! ```

use stashcache::client::cvmfs::{CvmfsClient, CVMFS_CHUNK};
use stashcache::config::defaults::paper_federation;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::sim::workload::FileRef;
use stashcache::util::{ByteSize, Pcg64};

fn main() {
    let mut fed = FedSim::build(paper_federation());
    fed.start_background_load(2);
    let mut rng = Pcg64::new(0x1160, 1);

    // 24 frame files of ~467 MB (the paper's median size).
    let frames: Vec<FileRef> = (0..24)
        .map(|i| FileRef {
            path: format!("/ospool/ligo/frames/O3/H-H1_GWOSC_O3a_{i:04}.gwf"),
            size: ByteSize(467_852_000),
            version: 1,
        })
        .collect();

    let sites = ["syracuse", "nebraska", "chicago"];
    let mut wan_before = Vec::new();
    for s in sites {
        let idx = fed.topo.site_index(s).unwrap();
        wan_before.push(fed.wan_bytes(idx));
    }

    // 60 jobs, each reading 4 random frames.
    let mut total_secs = 0.0;
    let mut hits = 0u32;
    let mut transfers = 0u32;
    for job in 0..60 {
        let site_name = sites[(job % sites.len()) as usize];
        let site = fed.topo.site_index(site_name).unwrap();
        for _ in 0..4 {
            let f = &frames[rng.gen_range(0, frames.len() as u64) as usize];
            let rec = fed.download(site, f, DownloadMethod::Stash);
            total_secs += rec.duration.as_secs_f64();
            transfers += 1;
            if rec.cache_hit {
                hits += 1;
            }
        }
    }
    println!(
        "campaign: {transfers} transfers, {:.1}% cache hits, mean {:.1}s/file",
        100.0 * hits as f64 / transfers as f64,
        total_secs / transfers as f64
    );

    for (i, s) in sites.iter().enumerate() {
        let idx = fed.topo.site_index(s).unwrap();
        let wan = fed.wan_bytes(idx) - wan_before[i];
        let cache = &fed.caches[&idx];
        println!(
            "{s:>9}: WAN bytes {:>10}, cache hit bytes {:>10}, resident {}",
            ByteSize(wan as u64),
            ByteSize(cache.stats.bytes_served_hit),
            cache.resident_files()
        );
    }

    // CVMFS partial read: a PyCBC-style job reads only the first 96 MB
    // of a frame — the client fetches 4 chunks, not 467 MB (§3.1).
    let mut cvmfs = CvmfsClient::default();
    let plan = cvmfs.plan_read(&frames[0].path, 0, 96_000_000, frames[0].size.as_u64());
    println!(
        "\ncvmfs partial read: app asked {} MB, client fetches {} chunks of {} MB ({} MB total)",
        96,
        plan.remote_chunks.len(),
        CVMFS_CHUNK / 1_000_000,
        plan.remote_chunks.iter().map(|&(_, _, l)| l).sum::<u64>() / 1_000_000
    );
    assert!(hits > transfers / 3, "working set must get hot");
    println!("ligo workflow OK");
}
