//! Dark Energy Survey processing campaign — Table 1's second-largest
//! user (709 TB over six months).
//!
//! DES coadd jobs read large catalog files; this example compares the
//! two distribution strategies the paper evaluates (site HTTP proxies
//! vs the StashCache federation) for the *same* campaign at a
//! well-connected site and a poorly-connected one, reproducing the
//! §5 conclusion: the proxy wins for small inputs, the federation for
//! multi-GB inputs — and the gap depends on the site.
//!
//! ```text
//! cargo run --release --example des_campaign
//! ```

use stashcache::config::defaults::paper_federation;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::sim::workload::FileRef;
use stashcache::util::ByteSize;

fn campaign(fed: &mut FedSim, site: &str, size: ByteSize, jobs: usize) -> (f64, f64) {
    let idx = fed.topo.site_index(site).unwrap();
    let mut http = 0.0;
    let mut stash = 0.0;
    for j in 0..jobs {
        // Each job reads one of 6 shared catalog shards.
        let f = FileRef {
            path: format!("/ospool/des/y3-coadd/shard{}-{}.fits", j % 6, size),
            size,
            version: 1,
        };
        http += fed
            .download(idx, &f, DownloadMethod::HttpProxy)
            .duration
            .as_secs_f64();
        stash += fed
            .download(idx, &f, DownloadMethod::Stash)
            .duration
            .as_secs_f64();
    }
    (http / jobs as f64, stash / jobs as f64)
}

fn main() {
    let mut fed = FedSim::build(paper_federation());
    fed.start_background_load(4);

    println!("DES campaign: mean seconds per input (24 jobs, 6 shared shards)\n");
    println!(
        "{:>12} {:>12} {:>12} {:>12} {:>10}",
        "site", "input size", "http proxy", "stashcache", "winner"
    );
    let mut federation_wins_large = 0;
    for site in ["nebraska", "bellarmine"] {
        for size in [ByteSize::mb(25), ByteSize(2_335_000_000)] {
            let (http, stash) = campaign(&mut fed, site, size, 24);
            let winner = if stash < http { "stash" } else { "http" };
            if size.as_u64() > 1_000_000_000 && stash < http {
                federation_wins_large += 1;
            }
            println!(
                "{site:>12} {:>12} {http:>11.2}s {stash:>11.2}s {winner:>10}",
                size.to_string()
            );
        }
    }
    assert!(
        federation_wins_large == 2,
        "federation must win the multi-GB inputs at both sites"
    );

    // Where did the bytes come from once the campaign warmed up?
    let total_hit: u64 = fed.caches.values().map(|c| c.stats.bytes_served_hit).sum();
    let total_fetch: u64 = fed
        .caches
        .values()
        .map(|c| c.stats.bytes_fetched_origin)
        .sum();
    println!(
        "\nfederation-wide: {} served from cache, {} fetched from origin ({}x amplification avoided)",
        ByteSize(total_hit),
        ByteSize(total_fetch),
        (total_hit + total_fetch) / total_fetch.max(1)
    );
    println!(
        "des usage recorded by monitoring: {:?}",
        fed.aggregator
            .experiment_usage("des")
            .map(|u| ByteSize(u.bytes_read))
    );
    println!("des campaign OK");
}
