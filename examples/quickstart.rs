//! Quickstart: build the paper's federation, download a file every way
//! the paper's clients can, and inspect what the system did.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use stashcache::config::defaults::paper_federation;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::sim::workload::FileRef;
use stashcache::util::ByteSize;

fn main() {
    // 1. The federation of the paper: 10 caches (Figure 2), 5 compute
    //    sites (§4.1), origins on the Stash filesystem at Chicago.
    let mut fed = FedSim::build(paper_federation());
    println!(
        "federation up: {} caches, {} proxies, {} origins, {} redirectors",
        fed.caches.len(),
        fed.proxies.len(),
        fed.origins.len(),
        fed.redirectors.instances.len()
    );

    // 2. A researcher's 2.3 GB dataset (the paper's 95th-pct file).
    let file = FileRef {
        path: "/ospool/ligo/data/quickstart.dat".into(),
        size: ByteSize(2_335_000_000),
        version: 1,
    };

    let site = fed.topo.site_index("syracuse").unwrap();

    // 3. Download via the HTTP proxy (baseline) and via StashCache,
    //    twice each — the four passes of §4.1.
    for (label, method) in [
        ("curl via HTTP proxy (cold)", DownloadMethod::HttpProxy),
        ("curl via HTTP proxy (hot) ", DownloadMethod::HttpProxy),
        ("stashcp via cache   (cold)", DownloadMethod::Stash),
        ("stashcp via cache   (hot) ", DownloadMethod::Stash),
    ] {
        let rec = fed.download(site, &file, method);
        println!(
            "{label}: {:>9.2} Mbps in {} (terminal hit: {})",
            rec.rate_mbps(),
            rec.duration,
            rec.cache_hit
        );
    }

    // 4. What the infrastructure saw.
    let cache = &fed.caches[&site];
    println!(
        "\nsyracuse cache: {} resident, usage {}, hit bytes {}, fetched {}",
        cache.resident_files(),
        cache.usage(),
        ByteSize(cache.stats.bytes_served_hit),
        ByteSize(cache.stats.bytes_fetched_origin),
    );
    let proxy = &fed.proxies[&site];
    println!(
        "syracuse proxy: {} objects, pass-through-too-large {}",
        proxy.object_count(),
        proxy.stats.passthrough_too_large
    );
    println!(
        "monitoring: {} reports aggregated, ligo usage {:?}",
        fed.aggregator.reports,
        fed.aggregator.experiment_usage("ligo").map(|u| ByteSize(u.bytes_read))
    );
}
