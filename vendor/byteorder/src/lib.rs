//! Offline subset of the `byteorder` crate API: the [`ByteOrder`]
//! trait for [`BigEndian`] / [`LittleEndian`], plus the
//! [`ReadBytesExt`] / [`WriteBytesExt`] extension traits over
//! `std::io` readers and writers. Only the fixed-width unsigned
//! integer codecs this workspace uses are provided.

use std::io::{self, Read, Write};

/// Byte-order parameterization for the extension traits.
pub trait ByteOrder {
    fn read_u16(buf: &[u8; 2]) -> u16;
    fn read_u32(buf: &[u8; 4]) -> u32;
    fn read_u64(buf: &[u8; 8]) -> u64;
    fn write_u16(buf: &mut [u8; 2], n: u16);
    fn write_u32(buf: &mut [u8; 4], n: u32);
    fn write_u64(buf: &mut [u8; 8], n: u64);
}

/// Network byte order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BigEndian {}

/// Least-significant byte first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LittleEndian {}

/// Alias matching the real crate.
pub type NetworkEndian = BigEndian;

impl ByteOrder for BigEndian {
    fn read_u16(buf: &[u8; 2]) -> u16 {
        u16::from_be_bytes(*buf)
    }
    fn read_u32(buf: &[u8; 4]) -> u32 {
        u32::from_be_bytes(*buf)
    }
    fn read_u64(buf: &[u8; 8]) -> u64 {
        u64::from_be_bytes(*buf)
    }
    fn write_u16(buf: &mut [u8; 2], n: u16) {
        *buf = n.to_be_bytes();
    }
    fn write_u32(buf: &mut [u8; 4], n: u32) {
        *buf = n.to_be_bytes();
    }
    fn write_u64(buf: &mut [u8; 8], n: u64) {
        *buf = n.to_be_bytes();
    }
}

impl ByteOrder for LittleEndian {
    fn read_u16(buf: &[u8; 2]) -> u16 {
        u16::from_le_bytes(*buf)
    }
    fn read_u32(buf: &[u8; 4]) -> u32 {
        u32::from_le_bytes(*buf)
    }
    fn read_u64(buf: &[u8; 8]) -> u64 {
        u64::from_le_bytes(*buf)
    }
    fn write_u16(buf: &mut [u8; 2], n: u16) {
        *buf = n.to_le_bytes();
    }
    fn write_u32(buf: &mut [u8; 4], n: u32) {
        *buf = n.to_le_bytes();
    }
    fn write_u64(buf: &mut [u8; 8], n: u64) {
        *buf = n.to_le_bytes();
    }
}

/// Read fixed-width integers from any `io::Read`.
pub trait ReadBytesExt: Read {
    fn read_u8(&mut self) -> io::Result<u8> {
        let mut buf = [0u8; 1];
        self.read_exact(&mut buf)?;
        Ok(buf[0])
    }

    fn read_u16<T: ByteOrder>(&mut self) -> io::Result<u16> {
        let mut buf = [0u8; 2];
        self.read_exact(&mut buf)?;
        Ok(T::read_u16(&buf))
    }

    fn read_u32<T: ByteOrder>(&mut self) -> io::Result<u32> {
        let mut buf = [0u8; 4];
        self.read_exact(&mut buf)?;
        Ok(T::read_u32(&buf))
    }

    fn read_u64<T: ByteOrder>(&mut self) -> io::Result<u64> {
        let mut buf = [0u8; 8];
        self.read_exact(&mut buf)?;
        Ok(T::read_u64(&buf))
    }
}

impl<R: Read + ?Sized> ReadBytesExt for R {}

/// Write fixed-width integers to any `io::Write`.
pub trait WriteBytesExt: Write {
    fn write_u8(&mut self, n: u8) -> io::Result<()> {
        self.write_all(&[n])
    }

    fn write_u16<T: ByteOrder>(&mut self, n: u16) -> io::Result<()> {
        let mut buf = [0u8; 2];
        T::write_u16(&mut buf, n);
        self.write_all(&buf)
    }

    fn write_u32<T: ByteOrder>(&mut self, n: u32) -> io::Result<()> {
        let mut buf = [0u8; 4];
        T::write_u32(&mut buf, n);
        self.write_all(&buf)
    }

    fn write_u64<T: ByteOrder>(&mut self, n: u64) -> io::Result<()> {
        let mut buf = [0u8; 8];
        T::write_u64(&mut buf, n);
        self.write_all(&buf)
    }
}

impl<W: Write + ?Sized> WriteBytesExt for W {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_big_endian() {
        let mut buf = Vec::new();
        buf.write_u8(0xab).unwrap();
        buf.write_u16::<BigEndian>(0x0102).unwrap();
        buf.write_u32::<BigEndian>(0x0304_0506).unwrap();
        buf.write_u64::<BigEndian>(0x0708_090a_0b0c_0d0e).unwrap();
        assert_eq!(buf[1..3], [0x01, 0x02]);
        let mut c = Cursor::new(buf);
        assert_eq!(c.read_u8().unwrap(), 0xab);
        assert_eq!(c.read_u16::<BigEndian>().unwrap(), 0x0102);
        assert_eq!(c.read_u32::<BigEndian>().unwrap(), 0x0304_0506);
        assert_eq!(c.read_u64::<BigEndian>().unwrap(), 0x0708_090a_0b0c_0d0e);
    }

    #[test]
    fn little_endian_differs() {
        let mut buf = Vec::new();
        buf.write_u16::<LittleEndian>(0x0102).unwrap();
        assert_eq!(buf, [0x02, 0x01]);
        let mut c = Cursor::new(buf);
        assert_eq!(c.read_u16::<LittleEndian>().unwrap(), 0x0102);
    }

    #[test]
    fn short_read_errors() {
        let mut c = Cursor::new(vec![0u8; 3]);
        assert!(c.read_u64::<BigEndian>().is_err());
    }
}
