//! Stub of the `xla` PJRT bindings used by `stashcache::runtime`.
//!
//! The offline build environment has no XLA/PJRT shared libraries, so
//! this crate provides the exact API surface the runtime layer
//! compiles against while reporting the backend as unavailable at
//! *client creation* time: [`PjRtClient::cpu`] always returns an
//! error, every caller already handles that path (the services fall
//! back to the pure-rust backends), and PJRT-gated tests skip.
//! Swapping this stub for the real bindings re-enables the
//! AOT-artifact executors without any source change in `stashcache`.

use std::fmt;

/// Error type for every stub operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: built against the offline `xla` stub \
         (vendor/xla); link the real xla bindings to enable AOT artifacts"
            .to_string(),
    )
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// A host literal (stub: carries no data — unreachable once client
/// creation fails).
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reinterpret with a new shape.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device buffer holding an execution result (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A PJRT client (stub — creation always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn literal_ops_error_cleanly() {
        let lit = Literal::vec1(&[0f32; 4]);
        assert!(lit.reshape(&[2, 2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
