//! Offline subset of the `anyhow` crate API.
//!
//! The build environment has no network access, so this vendored shim
//! provides the parts of `anyhow` the workspace actually uses: the
//! string-backed [`Error`] with context layering, the [`Context`]
//! extension trait for `Result` and `Option`, the `anyhow!`, `bail!`
//! and `ensure!` macros, and the [`Result`] alias. Unlike the real
//! crate it stringifies source errors instead of boxing them — ample
//! for error *reporting*, which is all this codebase does with it.

use std::fmt;

/// A context-layered error. `Display` shows the outermost layer;
/// `{:#}` (alternate) shows the whole chain outermost-first, separated
/// by `": "`, matching real anyhow's formatting.
pub struct Error {
    /// Root message first; each added context is pushed on the end.
    layers: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            layers: vec![message.to_string()],
        }
    }

    fn push_context(&mut self, context: String) {
        self.layers.push(context);
    }

    /// The outermost context (or the root message).
    fn outermost(&self) -> &str {
        self.layers.last().map(String::as_str).unwrap_or("")
    }

    /// Add context to this error (mirrors `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.push_context(context.to_string());
        self
    }

    /// The error chain, outermost first (shim-local helper).
    pub fn chain_messages(&self) -> impl Iterator<Item = &str> {
        self.layers.iter().rev().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, layer) in self.layers.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{layer}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.outermost())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outermost())?;
        if self.layers.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for layer in self.layers.iter().rev().skip(1) {
                write!(f, "\n    {layer}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes the blanket `From` below coherent (same trick as
// the real crate).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(source: E) -> Self {
        Error::msg(source)
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

pub(crate) mod ext {
    use super::Error;

    /// Anything `.context()` can be called through: a std error (which
    /// becomes the root of a new chain) or an existing [`Error`]
    /// (which gains a layer). Mirrors anyhow's private `ext::StdError`.
    pub trait StdError {
        fn ext_context(self, context: String) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context(self, context: String) -> Error {
            Error::msg(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context(self, context: String) -> Error {
            self.context(context)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn context_on_result_and_option() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");

        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("plain {}", 1);
        assert_eq!(e.to_string(), "plain 1");
        let from_string = anyhow!(String::from("already a message"));
        assert_eq!(from_string.to_string(), "already a message");
    }

    #[test]
    fn context_chains_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("root")
        }
        let e = inner().context("mid").context("top").unwrap_err();
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert_eq!(e.chain_messages().count(), 3);
    }
}
